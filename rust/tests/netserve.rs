//! TCP front-end, end to end over real sockets: concurrent connections
//! mixing streamed generation with attention requests must see exactly
//! the bytes the in-process API would produce — token streams bit-match
//! an in-process oracle server, attention fingerprints match oracle
//! outputs, load shedding answers busy over the wire, and shutdown
//! mid-stream is clean.

use conv_basis::attention::ExactKernel;
use conv_basis::coordinator::{
    fingerprint, AdmissionConfig, AttnRequest, Backend, GenConfig, GenRequest, NetConfig,
    NetServer, Payload, Server, ServerConfig,
};
use conv_basis::model::{AttentionBackend, ModelConfig, Transformer};
use conv_basis::tensor::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

fn model() -> Arc<Transformer> {
    let mut rng = Rng::seeded(42);
    Arc::new(Transformer::new(&ModelConfig::tiny(64), &mut rng))
}

fn cfg(model: Arc<Transformer>, admission: AdmissionConfig) -> ServerConfig {
    ServerConfig {
        workers: 2,
        gen: Some(GenConfig {
            model,
            backend: AttentionBackend::ConvStrided(4),
            max_concurrent: 4,
            admission,
            speculate: 0,
        }),
        ..Default::default()
    }
}

/// Exact-backend config with a server-wide speculation depth γ.
fn exact_cfg(model: Arc<Transformer>, speculate: usize) -> ServerConfig {
    ServerConfig {
        workers: 2,
        gen: Some(GenConfig {
            model,
            backend: AttentionBackend::Exact(ExactKernel::RowStream),
            max_concurrent: 4,
            admission: AdmissionConfig::default(),
            speculate,
        }),
        ..Default::default()
    }
}

/// Minimal flat-JSON field reader for the wire format under test.
fn jfield<'a>(line: &'a str, key: &str) -> &'a str {
    let pat = format!("\"{key}\":");
    let i = line.find(&pat).unwrap_or_else(|| panic!("no {key:?} in {line:?}")) + pat.len();
    let rest = &line[i..];
    let end = rest
        .char_indices()
        .find(|(_, c)| *c == ',' || *c == '}')
        .map(|(j, _)| j)
        .unwrap_or(rest.len());
    rest[..end].trim_matches('"')
}

fn ju(line: &str, key: &str) -> u64 {
    jfield(line, key).parse().unwrap_or_else(|_| panic!("bad uint {key:?} in {line:?}"))
}

/// What one client connection observed for its generation request.
struct ClientView {
    tokens: Vec<usize>,
    done_tokens: Vec<usize>,
    attn_line: String,
}

/// Drive one connection: a generate and an attn request, concurrently
/// outstanding, reading interleaved lines until both terminate.
fn run_client(addr: std::net::SocketAddr, c: usize) -> ClientView {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(
        writer,
        "{{\"op\":\"generate\",\"id\":{c},\"prompt\":[{},{},{}],\"max_new_tokens\":6}}",
        1 + c,
        2 + c,
        3 + c,
    )
    .unwrap();
    writeln!(writer, "{{\"op\":\"attn\",\"id\":{},\"seq_len\":128,\"d_model\":8,\"seed\":{c}}}", 100 + c)
        .unwrap();

    let mut view =
        ClientView { tokens: Vec::new(), done_tokens: Vec::new(), attn_line: String::new() };
    let (mut done, mut attn_done) = (false, false);
    let mut line = String::new();
    while !(done && attn_done) {
        line.clear();
        assert!(reader.read_line(&mut line).expect("read") > 0, "server closed early");
        let l = line.trim();
        match jfield(l, "ev") {
            "token" => {
                assert_eq!(ju(l, "id") as usize, c, "token routed to the wrong client id");
                assert_eq!(ju(l, "index") as usize, view.tokens.len(), "indices must be consecutive");
                view.tokens.push(ju(l, "token") as usize);
            }
            "done" => {
                assert_eq!(ju(l, "id") as usize, c);
                let arr = &l[l.find("\"tokens\":[").unwrap() + 10..];
                let arr = &arr[..arr.find(']').unwrap()];
                view.done_tokens =
                    arr.split(',').filter(|t| !t.is_empty()).map(|t| t.parse().unwrap()).collect();
                done = true;
            }
            "attn" => {
                assert_eq!(ju(l, "id") as usize, 100 + c);
                view.attn_line = l.to_string();
                attn_done = true;
            }
            other => panic!("unexpected event {other:?}: {l}"),
        }
    }
    view
}

#[test]
fn concurrent_connections_stream_bit_identical_tokens() {
    let model = model();
    let net = NetServer::start(cfg(model.clone(), AdmissionConfig::default()), NetConfig::default())
        .expect("bind");
    let addr = net.addr();

    let clients: Vec<ClientView> = (0..4usize)
        .map(|c| std::thread::spawn(move || run_client(addr, c)))
        .collect::<Vec<_>>()
        .into_iter()
        .map(|j| j.join().expect("client thread"))
        .collect();
    let net_metrics = net.shutdown();

    // Oracle: the same requests through the in-process API on an
    // identically configured server sharing the same model weights.
    let oracle = Server::start(cfg(model, AdmissionConfig::default()));
    for c in 0..4usize {
        oracle.submit_generate(GenRequest::new(c as u64, vec![1 + c, 2 + c, 3 + c], 6));
        oracle.submit(AttnRequest {
            id: 100 + c as u64,
            seq_len: 128,
            d_model: 8,
            bounded_entries: false,
            backend: None,
            payload: Payload::Synthetic { seed: c as u64 },
            submitted_at: Instant::now(),
        });
    }
    let mut gens = oracle.collect_generations(4);
    gens.sort_by_key(|g| g.id);
    let mut attns = oracle.collect(4);
    attns.sort_by_key(|r| r.id);
    oracle.shutdown();

    for (c, view) in clients.iter().enumerate() {
        assert_eq!(view.tokens.len(), 6, "client {c} streamed token count");
        assert_eq!(view.done_tokens, view.tokens, "done must repeat the stream");
        assert_eq!(view.tokens, gens[c].tokens, "client {c} tokens vs in-process oracle");

        let want_backend = match attns[c].backend {
            Backend::Exact => "exact",
            Backend::ConvBasis => "conv",
            Backend::LowRank => "lowrank",
        };
        assert_eq!(jfield(&view.attn_line, "backend"), want_backend);
        assert_eq!(ju(&view.attn_line, "basis_k") as usize, attns[c].basis_k);
        let want_fp = format!("{:016x}", fingerprint(attns[c].y.data()));
        assert_eq!(jfield(&view.attn_line, "y_fp"), want_fp, "client {c} attn fingerprint");
    }
    let s = net_metrics.snapshot();
    assert_eq!((s.gen_requests, s.gen_completed, s.gen_rejected), (4, 4, 0));
    assert_eq!(s.requests_submitted, 4);
}

#[test]
fn full_queue_sheds_busy_over_the_wire() {
    let model = model();
    let admission = AdmissionConfig { max_queue: 1, ..Default::default() };
    let mut cfg = cfg(model, admission);
    cfg.gen.as_mut().unwrap().max_concurrent = 1;
    let net = NetServer::start(cfg, NetConfig::default()).expect("bind");

    let stream = TcpStream::connect(net.addr()).expect("connect");
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    // 8 back-to-back submissions: with one decode slot and a queue of
    // one, most of the burst must shed.
    let mut burst = String::new();
    for i in 0..8 {
        burst.push_str(&format!(
            "{{\"op\":\"generate\",\"id\":{i},\"prompt\":[1,2,3],\"max_new_tokens\":8}}\n"
        ));
    }
    writer.write_all(burst.as_bytes()).unwrap();
    writer.flush().unwrap();

    let (mut done, mut busy) = (0usize, 0usize);
    let mut line = String::new();
    while done + busy < 8 {
        line.clear();
        assert!(reader.read_line(&mut line).expect("read") > 0, "server closed early");
        match jfield(line.trim(), "ev") {
            "done" => done += 1,
            "busy" => busy += 1,
            "token" => {}
            other => panic!("unexpected event {other:?}: {line}"),
        }
    }
    let s = net.shutdown().snapshot();
    assert!(busy >= 1, "a burst of 8 through a queue of 1 must shed");
    assert_eq!(busy as u64, s.shed_requests);
    assert_eq!(done as u64, s.gen_completed);
    assert_eq!(s.gen_requests, 8, "every submission is counted at the door");
}

#[test]
fn speculative_streams_bit_match_a_gamma_zero_oracle_server() {
    // Under speculation tokens arrive in per-round bursts, but each
    // client must still observe its exact γ = 0 stream: consecutive
    // indices, same tokens, same count, same terminal line. The
    // per-request `speculate` knob rides the wire: the server default
    // here is γ = 0, so any speculation observed in the metrics proves
    // the knob round-tripped.
    let model = model();
    let max_new = 8usize;
    let net = NetServer::start(exact_cfg(model.clone(), 0), NetConfig::default()).expect("bind");
    let addr = net.addr();
    let gammas = [1usize, 4, 8];
    let handles: Vec<_> = (0..3usize)
        .map(|c| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).expect("connect");
                stream.set_nodelay(true).unwrap();
                let mut writer = stream.try_clone().unwrap();
                let mut reader = BufReader::new(stream);
                writeln!(
                    writer,
                    "{{\"op\":\"generate\",\"id\":{c},\"prompt\":[{},{},{}],\
                     \"max_new_tokens\":{max_new},\"speculate\":{}}}",
                    1 + c,
                    2 + c,
                    3 + c,
                    gammas[c],
                )
                .unwrap();
                let mut tokens = Vec::new();
                let mut done_tokens: Vec<usize> = Vec::new();
                let mut line = String::new();
                loop {
                    line.clear();
                    assert!(reader.read_line(&mut line).expect("read") > 0, "closed early");
                    let l = line.trim();
                    match jfield(l, "ev") {
                        "token" => {
                            assert_eq!(ju(l, "id") as usize, c);
                            assert_eq!(
                                ju(l, "index") as usize,
                                tokens.len(),
                                "burst delivery must keep indices consecutive"
                            );
                            tokens.push(ju(l, "token") as usize);
                        }
                        "done" => {
                            let arr = &l[l.find("\"tokens\":[").unwrap() + 10..];
                            let arr = &arr[..arr.find(']').unwrap()];
                            done_tokens = arr
                                .split(',')
                                .filter(|t| !t.is_empty())
                                .map(|t| t.parse().unwrap())
                                .collect();
                            break;
                        }
                        other => panic!("unexpected event {other:?}: {l}"),
                    }
                }
                (tokens, done_tokens)
            })
        })
        .collect();
    let streams: Vec<(Vec<usize>, Vec<usize>)> =
        handles.into_iter().map(|h| h.join().expect("client")).collect();
    let s = net.shutdown().snapshot();
    assert!(s.spec_rounds >= 1, "the wire `speculate` knob must reach the scheduler");
    assert_eq!(s.spec_accepted, s.spec_drafted, "exact drafts always verify");
    assert_eq!(s.gen_completed, 3);

    // γ = 0 oracle server, same weights, in-process.
    let oracle = Server::start(exact_cfg(model, 0));
    for c in 0..3usize {
        oracle.submit_generate(GenRequest::new(c as u64, vec![1 + c, 2 + c, 3 + c], max_new));
    }
    let mut gens = oracle.collect_generations(3);
    gens.sort_by_key(|g| g.id);
    oracle.shutdown();
    for (c, (tokens, done_tokens)) in streams.iter().enumerate() {
        assert_eq!(tokens.len(), max_new, "client {c} token count");
        assert_eq!(done_tokens, tokens, "client {c}: done must repeat the stream");
        assert_eq!(tokens, &gens[c].tokens, "client {c}: speculative stream vs γ=0 oracle");
    }
}

#[test]
fn cancel_over_the_wire_frees_the_session_and_ends_with_cancelled() {
    let model = model();
    let net = NetServer::start(exact_cfg(model, 0), NetConfig::default()).expect("bind");
    let stream = TcpStream::connect(net.addr()).expect("connect");
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    // max_new far past max_seq room: ~60 decode rounds — plenty of
    // runway for the cancel line to land mid-flight.
    writeln!(writer, "{{\"op\":\"generate\",\"id\":5,\"prompt\":[5,6,7],\"max_new_tokens\":200}}")
        .unwrap();
    // Wait until the stream is live, then cancel (plus an unknown id,
    // which must answer with an error line and change nothing).
    let mut line = String::new();
    assert!(reader.read_line(&mut line).expect("read") > 0);
    assert_eq!(jfield(line.trim(), "ev"), "token");
    writeln!(writer, "{{\"op\":\"cancel\",\"id\":99}}").unwrap();
    writeln!(writer, "{{\"op\":\"cancel\",\"id\":5}}").unwrap();

    let mut streamed = 1usize;
    let mut saw_error = false;
    let terminal = loop {
        line.clear();
        assert!(reader.read_line(&mut line).expect("read") > 0, "closed early");
        let l = line.trim();
        match jfield(l, "ev") {
            "token" => streamed += 1,
            "error" => saw_error = true, // the unknown-id cancel
            ev => break ev.to_string(),
        }
    };
    assert_eq!(terminal, "cancelled", "cancel must end the stream with its own terminal");
    assert!(saw_error, "cancelling an unknown id answers with an error line");
    assert!(streamed < 61, "cancellation must cut generation short, saw {streamed} tokens");
    let s = net.shutdown().snapshot();
    assert_eq!(s.gen_cancelled, 1);
    assert_eq!(s.gen_completed, 0, "a cancelled generation is not a completion");
    assert_eq!(s.decode_resident_bytes, 0, "cancel must free the decode session's KV bytes");
    assert!(s.gen_tokens as usize >= streamed);
}

#[test]
fn backend_wire_knob_pins_past_the_router() {
    // seq_len 128 routes to conv by default (≥ exact_below); the
    // per-request `backend` knob must pin it to exact anyway, the
    // pinned output must bit-match an in-process oracle pinned the
    // same way, and a bogus knob value must answer with an error line.
    let model = model();
    let net = NetServer::start(cfg(model.clone(), AdmissionConfig::default()), NetConfig::default())
        .expect("bind");
    let stream = TcpStream::connect(net.addr()).expect("connect");
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(
        writer,
        "{{\"op\":\"attn\",\"id\":1,\"seq_len\":128,\"d_model\":8,\"seed\":3,\"backend\":\"exact\"}}"
    )
    .unwrap();
    writeln!(
        writer,
        "{{\"op\":\"attn\",\"id\":2,\"seq_len\":128,\"d_model\":8,\"seed\":3,\"backend\":\"warp\"}}"
    )
    .unwrap();

    let (mut attn_line, mut saw_error) = (String::new(), false);
    let mut line = String::new();
    while attn_line.is_empty() || !saw_error {
        line.clear();
        assert!(reader.read_line(&mut line).expect("read") > 0, "server closed early");
        let l = line.trim();
        match jfield(l, "ev") {
            "attn" => {
                assert_eq!(ju(l, "id"), 1);
                attn_line = l.to_string();
            }
            "error" => saw_error = true,
            other => panic!("unexpected event {other:?}: {l}"),
        }
    }
    let s = net.shutdown().snapshot();
    assert_eq!(jfield(&attn_line, "backend"), "exact", "the knob must win over the router");
    assert_eq!(ju(&attn_line, "basis_k"), 0, "exact serving uses no conv basis");
    assert_eq!(s.requests_submitted, 1, "the rejected knob value never reaches the server");

    // In-process oracle, pinned the same way: same bits on the wire.
    let oracle = Server::start(cfg(model, AdmissionConfig::default()));
    oracle.submit(AttnRequest {
        id: 1,
        seq_len: 128,
        d_model: 8,
        bounded_entries: false,
        backend: Some(Backend::Exact),
        payload: Payload::Synthetic { seed: 3 },
        submitted_at: Instant::now(),
    });
    let resp = &oracle.collect(1)[0];
    oracle.shutdown();
    assert!(matches!(resp.backend, Backend::Exact));
    let want_fp = format!("{:016x}", fingerprint(resp.y.data()));
    assert_eq!(jfield(&attn_line, "y_fp"), want_fp, "pinned request bit-matches the oracle");
}

#[test]
fn shutdown_mid_stream_is_clean() {
    let model = model();
    let net =
        NetServer::start(cfg(model, AdmissionConfig::default()), NetConfig::default()).expect("bind");

    let stream = TcpStream::connect(net.addr()).expect("connect");
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(writer, "{{\"op\":\"generate\",\"id\":1,\"prompt\":[5,6,7],\"max_new_tokens\":40}}")
        .unwrap();
    // Wait for the stream to actually start, then pull the plug.
    let mut line = String::new();
    assert!(reader.read_line(&mut line).expect("read") > 0);
    assert_eq!(jfield(line.trim(), "ev"), "token");

    let s = net.shutdown().snapshot();
    assert_eq!(s.gen_requests, 1);
    assert!(s.gen_tokens >= 1, "at least the streamed token decoded");
    // The client's socket is closed: reads drain to EOF without hanging.
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
}
