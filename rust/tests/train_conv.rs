//! End-to-end conv-basis training harness (ISSUE 5).
//!
//! The paper's headline training claim — attention forward AND backward
//! both in almost linear time (Theorem 5.6; arXiv:2408.13233 for the
//! multi-layer chain) — only holds end to end when both halves share
//! one low-complexity structure instead of rebuilding it. These tests
//! pin the three legs of that claim on the real training loops:
//!
//! 1. **Parity** — a conv-trained LM's loss curve tracks the
//!    exact-trained curve within the documented [`CONV_TRAIN_RTOL`] at
//!    every logged step (n ∈ {8, 32}), bit-identically across engine
//!    worker counts 1/2/8.
//! 2. **Single recovery** — engine counters prove each (record, layer,
//!    head) basis is recovered exactly **once** per optimizer step
//!    (`step_recoveries`, not 2×), consumed exactly once by the
//!    backward (`step_basis_hits` == backward consumptions), with
//!    **zero traffic on the serving `BasisCache` shards**.
//! 3. **Fallback totality** — with a hostile recovery budget
//!    (k_max = 0) every head falls back, the fallbacks are *counted*
//!    (engine counters + per-step `TrainLog` accounting), and the run
//!    is **bit-identical** to exact-mode training — a failed recovery
//!    degrades cost, never the curve.

use conv_basis::attention::batched::{BatchedEngine, EngineConfig};
use conv_basis::attention::ExactKernel;
use conv_basis::basis::RecoverConfig;
use conv_basis::gradient::batched::{AttnBackwardMode, FastGradConfig};
use conv_basis::model::{
    train_classifier_with_engine, train_lm_with_engine, AttentionBackend, ModelConfig,
    TrainAttentionMode, TrainConfig, TrainLog, Transformer,
};
use conv_basis::tensor::max_abs_diff;

/// Documented conv-training parity tolerance: per logged step the
/// conv-trained loss must satisfy
/// `|conv − exact| < CONV_TRAIN_ATOL + CONV_TRAIN_RTOL·|exact|`.
///
/// With an exact recovery budget the conv operator equals the softmax
/// matrix to FFT rounding (~1e-8 per step — `tests/gradient_oracle.rs`
/// pins the per-step gradient at 1e-6 relative), but training
/// *compounds* per-step differences through the optimizer, so the
/// curve-level bound is deliberately looser than the per-step one —
/// the same 10%/0.05 envelope PR 4 established for the fast-backward
/// curve, now covering the conv forward too.
const CONV_TRAIN_RTOL: f64 = 0.10;
const CONV_TRAIN_ATOL: f64 = 0.05;

fn lm_cfg(seq_len: usize) -> (ModelConfig, TrainConfig) {
    let mcfg = ModelConfig {
        vocab_size: 260,
        d_model: 16,
        n_heads: 2,
        n_layers: 2,
        d_ff: 32,
        max_seq: seq_len,
    };
    let tcfg = TrainConfig {
        steps: 12,
        lr: 3e-3,
        seq_len,
        batch: 2,
        log_every: 1, // log EVERY step — the parity claim is per step
        seed: 5,
    };
    (mcfg, tcfg)
}

fn conv_mode(n: usize) -> (TrainAttentionMode, AttnBackwardMode) {
    let recover = RecoverConfig::exact(n);
    (
        TrainAttentionMode::Conv(recover),
        AttnBackwardMode::Fast(FastGradConfig { recover, use_cache: false }),
    )
}

fn run_lm(
    mcfg: &ModelConfig,
    tcfg: &TrainConfig,
    workers: usize,
    fwd: &TrainAttentionMode,
    bwd: &AttnBackwardMode,
) -> (Transformer, TrainLog, BatchedEngine) {
    let engine = BatchedEngine::new(EngineConfig { workers, cache_capacity: 32 });
    let (m, log) = train_lm_with_engine(mcfg, tcfg, 2000, &engine, fwd, bwd);
    (m, log, engine)
}

/// Bitwise equality over every parameter group of two trained models.
fn assert_models_bit_identical(a: &Transformer, b: &Transformer, ctx: &str) {
    assert_eq!(max_abs_diff(&a.embed, &b.embed), 0.0, "{ctx}: embed");
    assert_eq!(max_abs_diff(&a.head, &b.head), 0.0, "{ctx}: head");
    assert_eq!(max_abs_diff(&a.cls_head, &b.cls_head), 0.0, "{ctx}: cls_head");
    assert_eq!(a.lnf_g, b.lnf_g, "{ctx}: lnf_g");
    for (li, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        assert_eq!(la.ln1_g, lb.ln1_g, "{ctx}: layer {li} ln1_g");
        assert_eq!(la.ln2_g, lb.ln2_g, "{ctx}: layer {li} ln2_g");
        for (ma, mb, name) in [
            (&la.wq, &lb.wq, "wq"),
            (&la.wk, &lb.wk, "wk"),
            (&la.wv, &lb.wv, "wv"),
            (&la.wo, &lb.wo, "wo"),
            (&la.w1, &lb.w1, "w1"),
            (&la.w2, &lb.w2, "w2"),
        ] {
            assert_eq!(max_abs_diff(ma, mb), 0.0, "{ctx}: layer {li} {name}");
        }
    }
}

#[test]
fn conv_train_lm_tracks_exact_within_tolerance_and_is_bit_identical_across_workers() {
    // The archetype headline: for n ∈ {8, 32}, conv-mode training's
    // loss curve tracks exact-mode training within CONV_TRAIN_RTOL at
    // every step, and the conv run is bit-identical for engine worker
    // counts 1/2/8 (training jobs are pure; results input-ordered).
    for n in [8usize, 32] {
        let (mcfg, tcfg) = lm_cfg(n);
        let (_, log_exact, _) = run_lm(
            &mcfg,
            &tcfg,
            2,
            &TrainAttentionMode::Exact,
            &AttnBackwardMode::Exact(ExactKernel::RowStream),
        );
        let (fwd, bwd) = conv_mode(n);
        let (m1, log1, _) = run_lm(&mcfg, &tcfg, 1, &fwd, &bwd);
        for workers in [2usize, 8] {
            let (mw, logw, _) = run_lm(&mcfg, &tcfg, workers, &fwd, &bwd);
            assert_eq!(
                log1.losses, logw.losses,
                "n={n}: conv curve must be bit-identical for {workers} workers"
            );
            assert_eq!(log1.final_loss, logw.final_loss, "n={n} workers={workers}");
            assert_models_bit_identical(&m1, &mw, &format!("n={n} workers={workers}"));
        }
        assert_eq!(log_exact.losses.len(), log1.losses.len());
        assert_eq!(log_exact.losses.len(), tcfg.steps, "log_every=1 logs every step");
        for ((se, le), (sc, lc)) in log_exact.losses.iter().zip(&log1.losses) {
            assert_eq!(se, sc);
            let tol = CONV_TRAIN_ATOL + CONV_TRAIN_RTOL * le.abs();
            assert!(
                (le - lc).abs() < tol,
                "n={n}: conv curve diverged at step {se}: exact={le} conv={lc}"
            );
        }
    }
}

#[test]
fn conv_train_recovers_each_basis_exactly_once_per_step() {
    // The single-recovery pin, via engine counters: with batch = 1,
    // recoveries per step == layers × heads — NOT 2× (the backward
    // consumes the forward's handle instead of re-recovering) — and
    // step_basis_hits == backward consumptions, with zero serving-cache
    // traffic and zero dead writes into the shards.
    let n = 16usize;
    let mcfg = ModelConfig {
        vocab_size: 260,
        d_model: 16,
        n_heads: 2,
        n_layers: 2,
        d_ff: 32,
        max_seq: n,
    };
    let tcfg =
        TrainConfig { steps: 6, lr: 3e-3, seq_len: n, batch: 1, log_every: 2, seed: 9 };
    let (fwd, bwd) = conv_mode(n);
    let (_, log, engine) = run_lm(&mcfg, &tcfg, 2, &fwd, &bwd);
    let snap = engine.metrics().snapshot();

    let per_step = (tcfg.batch * mcfg.n_layers * mcfg.n_heads) as u64;
    let total = tcfg.steps as u64 * per_step;
    // Forward: one conv training submit per layer per step, each
    // spanning the micro-batch; every job recovered fresh, exactly once.
    assert_eq!(snap.train_fwd_conv_calls, (tcfg.steps * mcfg.n_layers) as u64);
    assert_eq!(snap.train_fwd_conv_jobs, total);
    assert_eq!(snap.step_recoveries, total, "recoveries per step == layers×heads, not 2×");
    assert_eq!(snap.train_fwd_fallbacks, 0, "exact-budget recovery cannot fail");
    // Backward: every (record, layer, head) job consumed the forward's
    // handle — one hit per recovery, no misses, no re-recovery.
    assert_eq!(snap.lm_backward_jobs, total);
    assert_eq!(snap.step_basis_hits, total, "step_basis_hits == backward consumptions");
    assert_eq!(snap.step_basis_misses, 0);
    assert_eq!(snap.lm_backward_fallbacks, 0);
    assert_eq!(snap.grad_fallbacks, 0);
    // Serving shards untouched: no lookups, no writes, nothing evicted.
    assert_eq!((snap.cache_hits, snap.cache_misses), (0, 0));
    assert_eq!(
        (snap.lm_backward_cache_hits, snap.lm_backward_cache_misses),
        (0, 0),
        "the handle path never reaches the serving-cache accounting"
    );
    assert_eq!(engine.cache().stats(), (0, 0, 0), "zero writes to the serving BasisCache");
    // Per-step TrainLog accounting exists and is all-clean here.
    assert_eq!(log.step_fwd_fallbacks, vec![0; tcfg.steps]);
    assert!(log.final_loss.is_finite());
}

#[test]
fn conv_train_kmax0_falls_back_counted_and_bit_matches_exact_training() {
    // Hostile recovery budget (k_max = 0): every (record, layer, head)
    // recovery fails on every step. The run must (a) count every
    // fallback — engine counters AND the per-step TrainLog — and
    // (b) be bit-identical to exact-mode training end to end: the
    // forward fallback replays the exact training kernel and retains
    // probs, so the backward's dense fallback replays the exact
    // backward. Cost degrades; the curve does not.
    let n = 16usize;
    let (mcfg, mut tcfg) = lm_cfg(n);
    tcfg.steps = 8;
    let (m_exact, log_exact, _) = run_lm(
        &mcfg,
        &tcfg,
        2,
        &TrainAttentionMode::Exact,
        &AttnBackwardMode::Exact(ExactKernel::RowStream),
    );

    let hostile = RecoverConfig { k_max: 0, t: 1, delta: 1.0, eps: 0.0 };
    let fwd = TrainAttentionMode::Conv(hostile);
    let bwd = AttnBackwardMode::Fast(FastGradConfig { recover: hostile, use_cache: false });
    let (m_conv, log_conv, engine) = run_lm(&mcfg, &tcfg, 2, &fwd, &bwd);

    assert_eq!(log_exact.losses, log_conv.losses, "curve must bit-match exact training");
    assert_eq!(log_exact.final_loss, log_conv.final_loss);
    assert_models_bit_identical(&m_exact, &m_conv, "kmax0-vs-exact");

    let per_step = tcfg.batch * mcfg.n_layers * mcfg.n_heads;
    let total = (tcfg.steps * per_step) as u64;
    let snap = engine.metrics().snapshot();
    assert_eq!(snap.train_fwd_fallbacks, total, "every forward recovery fell back");
    assert_eq!(snap.lm_backward_fallbacks, total, "every backward recovery fell back");
    assert_eq!(snap.grad_fallbacks, total, "shared training alarm counter");
    assert_eq!(snap.step_recoveries, 0);
    assert_eq!(snap.step_basis_hits, 0);
    assert_eq!(snap.step_basis_misses, total, "no handle existed for any head");
    assert_eq!(engine.cache().stats(), (0, 0, 0), "fallbacks still bypass the serving cache");
    // Per-step accounting: every step reports its full fallback load.
    assert_eq!(log_conv.step_fwd_fallbacks, vec![per_step; tcfg.steps]);
}

#[test]
fn forward_train_batch_bitmatches_per_record_forwards() {
    // The training forward's output contract, both modes:
    // * Exact — bit-identical to the PR-4 per-record training forward
    //   (`forward(…, Exact, keep_cache=true)`);
    // * Conv — bit-identical to the serving conv forward over the same
    //   weights (`AttentionBackend::ConvBasis`, same recovery config,
    //   same float-op path), per record.
    let mut rng = conv_basis::tensor::Rng::seeded(77);
    let mcfg = ModelConfig {
        vocab_size: 64,
        d_model: 16,
        n_heads: 2,
        n_layers: 2,
        d_ff: 32,
        max_seq: 24,
    };
    let m = Transformer::new(&mcfg, &mut rng);
    let engine = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 32 });
    let seqs: Vec<Vec<usize>> = vec![
        (0..12).map(|_| rng.below(64)).collect(),
        (0..24).map(|_| rng.below(64)).collect(),
        (0..8).map(|_| rng.below(64)).collect(),
    ];

    let (recs, fallbacks) =
        m.forward_train_batch(&seqs, &TrainAttentionMode::Exact, &engine);
    assert_eq!(fallbacks, 0);
    for (rec, tokens) in recs.iter().zip(&seqs) {
        let want = m.forward(tokens, &AttentionBackend::Exact(ExactKernel::RowStream), true);
        assert_eq!(max_abs_diff(&rec.logits, &want.logits), 0.0, "exact-mode logits");
        assert_eq!(
            max_abs_diff(&rec.final_hidden, &want.final_hidden),
            0.0,
            "exact-mode hidden"
        );
    }

    let cfg = RecoverConfig::exact(24);
    let (recs, fallbacks) =
        m.forward_train_batch(&seqs, &TrainAttentionMode::Conv(cfg), &engine);
    assert_eq!(fallbacks, 0, "exact-budget recovery cannot fail");
    for (rec, tokens) in recs.iter().zip(&seqs) {
        let want = m.forward(tokens, &AttentionBackend::ConvBasis(cfg), false);
        assert_eq!(max_abs_diff(&rec.logits, &want.logits), 0.0, "conv-mode logits");
    }
    assert_eq!(engine.cache().stats(), (0, 0, 0), "training forwards skip the serving cache");
}

#[test]
fn conv_train_classifier_tracks_exact() {
    // The classifier loop rides the same machinery: conv-mode curve
    // within the documented tolerance of exact-mode, bit-identical
    // across worker counts.
    let seq = 24usize;
    let mcfg = ModelConfig {
        vocab_size: 260,
        d_model: 16,
        n_heads: 2,
        n_layers: 1,
        d_ff: 32,
        max_seq: seq,
    };
    let ds = conv_basis::data::SentimentDataset::generate(24, 8, 31);
    let tcfg =
        TrainConfig { steps: 10, lr: 3e-3, seq_len: seq, batch: 2, log_every: 1, seed: 13 };
    let run = |workers: usize, fwd: &TrainAttentionMode, bwd: &AttnBackwardMode| {
        let engine = BatchedEngine::new(EngineConfig { workers, cache_capacity: 32 });
        train_classifier_with_engine(&mcfg, &tcfg, &ds, &engine, fwd, bwd)
    };
    let (_, log_exact) =
        run(2, &TrainAttentionMode::Exact, &AttnBackwardMode::Exact(ExactKernel::RowStream));
    let (fwd, bwd) = conv_mode(seq);
    let (_, log_a) = run(1, &fwd, &bwd);
    let (_, log_b) = run(8, &fwd, &bwd);
    assert_eq!(log_a.losses, log_b.losses, "worker count must not change the conv curve");
    for ((se, le), (sc, lc)) in log_exact.losses.iter().zip(&log_a.losses) {
        assert_eq!(se, sc);
        let tol = CONV_TRAIN_ATOL + CONV_TRAIN_RTOL * le.abs();
        assert!(
            (le - lc).abs() < tol,
            "classifier conv curve diverged at step {se}: exact={le} conv={lc}"
        );
    }
}

#[test]
#[should_panic(expected = "TrainAttentionMode::Conv requires AttnBackwardMode::Fast")]
fn conv_forward_with_exact_backward_is_rejected_up_front() {
    let (mcfg, tcfg) = lm_cfg(8);
    let engine = BatchedEngine::new(EngineConfig { workers: 1, cache_capacity: 8 });
    let _ = train_lm_with_engine(
        &mcfg,
        &tcfg,
        2000,
        &engine,
        &TrainAttentionMode::Conv(RecoverConfig::exact(8)),
        &AttnBackwardMode::Exact(ExactKernel::RowStream),
    );
}
