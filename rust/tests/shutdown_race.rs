//! Stable-toolchain shutdown-race regressions for the generation
//! scheduler — the always-run twins of `tests/loom_models.rs` (which
//! needs `--cfg loom`). Three historical hazards are pinned:
//!
//! 1. A scheduler parked on the admission condvar with nothing queued
//!    must observe shutdown and exit — no lost-wakeup hang
//!    ([`parked_scheduler_shutdown_does_not_hang`], wall-clock
//!    watchdog).
//! 2. A submission burst immediately followed by shutdown must drain:
//!    every request gets exactly one terminal event, none are dropped
//!    ([`shutdown_after_burst_drops_no_queued_flight`]).
//! 3. Submitters racing shutdown on the raw [`AdmissionQueue`]: every
//!    submission is accepted XOR shed, every accepted one is admitted,
//!    and the scheduler loop terminates
//!    ([`admission_race_accounts_every_request`]).

use conv_basis::attention::ExactKernel;
use conv_basis::coordinator::{
    AdmissionConfig, AdmissionQueue, GenConfig, GenEvent, GenRequest, GenSink, Metrics, Server,
    ServerConfig, Wake,
};
use conv_basis::model::{AttentionBackend, ModelConfig, Transformer};
use conv_basis::tensor::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

fn tiny_model(seed: u64) -> Arc<Transformer> {
    let mut rng = Rng::seeded(seed);
    Arc::new(Transformer::new(&ModelConfig::tiny(64), &mut rng))
}

fn gen_server(seed: u64) -> Server {
    Server::start(ServerConfig {
        gen: Some(GenConfig {
            model: tiny_model(seed),
            backend: AttentionBackend::Exact(ExactKernel::RowStream),
            max_concurrent: 4,
            admission: AdmissionConfig::default(),
            speculate: 0,
        }),
        cache_capacity: 64,
        ..Default::default()
    })
}

/// Shutdown must reach a scheduler that is parked (not spinning) on
/// the admission condvar. A lost wakeup here hangs `shutdown()`
/// forever, so the whole lifecycle runs on a watchdogged thread.
#[test]
fn parked_scheduler_shutdown_does_not_hang() {
    let (done_tx, done_rx) = mpsc::channel();
    thread::spawn(move || {
        let server = gen_server(42);
        // Give the scheduler time to reach its condvar park with an
        // empty queue — the exact state a lost wakeup would strand.
        thread::sleep(Duration::from_millis(50));
        let snap = server.shutdown().snapshot();
        let _ = done_tx.send(snap);
    });
    let snap = done_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("shutdown hung: the parked scheduler never observed the shutdown wakeup");
    assert_eq!(snap.gen_requests, 0);
    assert_eq!(snap.queue_depth, 0);
}

/// Queued flights survive shutdown: `Wake::Shutdown` is reported only
/// once the waiting line *and* the in-flight batch are drained, so a
/// burst submitted just before `shutdown()` must produce exactly one
/// terminal event per request — all `Done`, none silently dropped.
#[test]
fn shutdown_after_burst_drops_no_queued_flight() {
    const K: u64 = 8;
    let server = gen_server(7);
    // Let the scheduler park first so the burst races a parked waiter
    // (the same state as test 1) rather than a spinning one.
    thread::sleep(Duration::from_millis(20));
    let terminals = Arc::new(AtomicUsize::new(0));
    let done = Arc::new(AtomicUsize::new(0));
    for i in 0..K {
        let (t, d) = (Arc::clone(&terminals), Arc::clone(&done));
        let sink = GenSink::new(move |e| match e {
            GenEvent::Token { .. } => {}
            GenEvent::Done { tokens, .. } => {
                assert_eq!(tokens.len(), 2, "drained flights decode their full budget");
                t.fetch_add(1, Ordering::SeqCst);
                d.fetch_add(1, Ordering::SeqCst);
            }
            GenEvent::Rejected { .. } | GenEvent::Busy { .. } | GenEvent::Cancelled { .. } => {
                t.fetch_add(1, Ordering::SeqCst);
            }
        });
        server.submit_generate(GenRequest::new(i, vec![1, 2, 3], 2).with_stream(sink));
    }
    // Shutdown races the still-queued burst (max_concurrent is 4, so
    // at least one admission wave happens after this call).
    let snap = server.shutdown().snapshot();
    assert_eq!(terminals.load(Ordering::SeqCst) as u64, K, "one terminal event per request");
    assert_eq!(done.load(Ordering::SeqCst) as u64, K, "every queued flight completed");
    assert_eq!(snap.gen_requests, K);
    assert_eq!(snap.gen_completed, K);
    assert_eq!(snap.queue_depth, 0);
}

/// Submitter threads race shutdown on the raw admission queue (the
/// protocol `generation_loop` runs): accounting must close exactly —
/// accepted + shed == submitted, admitted == accepted, depth gauge
/// back to zero — and the scheduler loop must terminate.
#[test]
fn admission_race_accounts_every_request() {
    const SUBMITTERS: usize = 4;
    const PER: usize = 16;
    for round in 0..8u64 {
        let metrics = Arc::new(Metrics::new());
        // A tiny queue bound forces the shed path to race too.
        let q = Arc::new(AdmissionQueue::new(
            AdmissionConfig { max_queue: 4, ..Default::default() },
            Arc::clone(&metrics),
        ));
        let accepted = Arc::new(AtomicUsize::new(0));
        let shed = Arc::new(AtomicUsize::new(0));
        let scheduler = {
            let q = Arc::clone(&q);
            thread::spawn(move || {
                let mut seen = 0u64;
                let mut admitted = 0usize;
                loop {
                    match q.wait_for_work(&mut seen) {
                        Wake::Work => admitted += q.admit(0, 0, 0, usize::MAX).len(),
                        Wake::Shutdown => break admitted,
                    }
                }
            })
        };
        let submitters: Vec<_> = (0..SUBMITTERS)
            .map(|t| {
                let (q, acc, sh) = (Arc::clone(&q), Arc::clone(&accepted), Arc::clone(&shed));
                thread::spawn(move || {
                    for i in 0..PER {
                        match q.submit(GenRequest::new((t * PER + i) as u64, vec![1, 2], 1)) {
                            Ok(()) => {
                                acc.fetch_add(1, Ordering::SeqCst);
                            }
                            Err(_) => {
                                sh.fetch_add(1, Ordering::SeqCst);
                            }
                        }
                        if i % 3 == 0 {
                            thread::yield_now();
                        }
                    }
                })
            })
            .collect();
        for s in submitters {
            s.join().unwrap();
        }
        q.shutdown();
        let admitted = scheduler.join().expect("scheduler loop must terminate after shutdown");
        let (acc, sh) = (accepted.load(Ordering::SeqCst), shed.load(Ordering::SeqCst));
        assert_eq!(acc + sh, SUBMITTERS * PER, "round {round}: every submit resolved");
        assert_eq!(admitted, acc, "round {round}: every accepted request was admitted");
        let snap = metrics.snapshot();
        assert_eq!(snap.queue_depth, 0, "round {round}");
        assert_eq!(snap.shed_requests as usize, sh, "round {round}");
    }
}
