//! Loom models for the coordinator's scheduler protocol, the worker
//! pool, and the striped basis cache — compiled only under
//! `RUSTFLAGS="--cfg loom"` (the dedicated CI job):
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom_models
//! ```
//!
//! Under that cfg the whole crate builds against `crate::sync`'s loom
//! side, so the primitives these models drive are the very ones
//! production uses. The in-tree `loom` is `rust/loom-stub` (this image
//! vendors no external crates): `loom::model` degrades to an iterated
//! stress loop (`LOOM_STUB_ITERS`, default 64) over std primitives
//! instead of exhaustive interleaving — repoint the path dependency in
//! `rust/Cargo.toml` at the real crate to model-check exhaustively;
//! the models themselves are written to real-loom discipline (state
//! constructed inside `model`, ≤ 3 threads alive at once, bounded
//! loops).
//!
//! What is pinned here and nowhere else:
//!
//! * **No lost dispatcher kick** — `kick()` concurrent with a parking
//!   `wait_for_work` must wake it with the cursor advanced, never hang
//!   ([`kick_is_never_lost`], [`push_then_kick_is_visible`]).
//! * **Shutdown drains, never drops** — every accepted submission is
//!   admitted before `Wake::Shutdown` is reported, under concurrent
//!   submit/shutdown ([`shutdown_drains_queued_submissions`]).
//! * **Cancel/admit race** — a queued request is admitted XOR
//!   cancelled, exactly once ([`cancel_vs_admit_exactly_one_winner`]).
//! * **Pool fan-out order** — `WorkerPool::map` restores input order
//!   whatever the interleaving ([`pool_map_restores_input_order`]).
//! * **Striped cache coherence** — concurrent put/get on distinct
//!   (layer, head) shards: own get-after-put hits, aggregated stats
//!   stay coherent ([`cache_striped_put_get_is_coherent`]).
//!
//! The stable-toolchain twins of the scheduler models (wall-clock
//! watchdogs, full `Server` lifecycle) run unconditionally in
//! `tests/shutdown_race.rs`.
#![cfg(loom)]

use conv_basis::basis::{ConvBasis, KConvBasis};
use conv_basis::coordinator::{
    AdmissionConfig, AdmissionQueue, BasisCache, CacheKey, CachedBasis, GenRequest, Metrics, Wake,
};
use conv_basis::runtime::pool::WorkerPool;
use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::{Arc, Mutex};
use loom::thread;

fn queue(cfg: AdmissionConfig) -> (Arc<AdmissionQueue>, Arc<Metrics>) {
    let m = Arc::new(Metrics::new());
    (Arc::new(AdmissionQueue::new(cfg, Arc::clone(&m))), m)
}

fn req(id: u64, prompt_len: usize, max_new: usize) -> GenRequest {
    GenRequest::new(id, vec![1; prompt_len], max_new)
}

fn dummy_basis(n: usize) -> CachedBasis {
    CachedBasis {
        post_basis: KConvBasis::new(n, vec![ConvBasis { b: vec![1.0; n], m: n }]),
        d_tilde: vec![1.0; n],
    }
}

/// A kick racing a parking scheduler is never lost: `wait_for_work`
/// returns `Work` with the kick cursor advanced, in every interleaving
/// (kick before the park, during lock acquisition, after the park).
#[test]
fn kick_is_never_lost() {
    loom::model(|| {
        let (q, _m) = queue(AdmissionConfig::default());
        let q2 = Arc::clone(&q);
        let kicker = thread::spawn(move || q2.kick());
        let mut seen = 0u64;
        assert_eq!(q.wait_for_work(&mut seen), Wake::Work, "kick must wake the scheduler");
        assert_eq!(seen, 1, "the consumed kick advances the cursor");
        kicker.join().unwrap();
    });
}

/// State published before `kick()` is visible after the kicked wake:
/// the queue mutex orders the producer's batch push before the
/// scheduler's `Wake::Work`, so a woken scheduler never sees an empty
/// batch table (the missed-flush bug the kick counter exists to kill).
#[test]
fn push_then_kick_is_visible() {
    loom::model(|| {
        let (q, _m) = queue(AdmissionConfig::default());
        let batches: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        let (q2, b2) = (Arc::clone(&q), Arc::clone(&batches));
        let producer = thread::spawn(move || {
            b2.lock().unwrap().push(7);
            q2.kick();
        });
        let mut seen = 0u64;
        assert_eq!(q.wait_for_work(&mut seen), Wake::Work);
        // The waiting line is empty, so the wake can only be the kick —
        // and the kick happens-after the push.
        assert_eq!(seen, 1);
        assert_eq!(*batches.lock().unwrap(), vec![7], "pre-kick publish must be visible");
        producer.join().unwrap();
    });
}

/// Shutdown racing a submitter: every accepted request is admitted
/// before the scheduler observes `Wake::Shutdown` — accepted work is
/// never dropped, post-shutdown work is shed, and the loop terminates.
#[test]
fn shutdown_drains_queued_submissions() {
    loom::model(|| {
        let (q, m) = queue(AdmissionConfig::default());
        let accepted = Arc::new(AtomicUsize::new(0));
        let (qs, acc) = (Arc::clone(&q), Arc::clone(&accepted));
        let submitter = thread::spawn(move || {
            for i in 0..2u64 {
                if qs.submit(req(i, 2, 1)).is_ok() {
                    acc.fetch_add(1, Ordering::SeqCst);
                }
            }
        });
        let qstop = Arc::clone(&q);
        let stopper = thread::spawn(move || qstop.shutdown());
        let mut seen = 0u64;
        let mut admitted = 0usize;
        loop {
            match q.wait_for_work(&mut seen) {
                Wake::Work => admitted += q.admit(0, 0, 0, 8).len(),
                Wake::Shutdown => break,
            }
        }
        submitter.join().unwrap();
        stopper.join().unwrap();
        assert_eq!(
            admitted,
            accepted.load(Ordering::SeqCst),
            "Shutdown reported before the waiting line drained"
        );
        assert_eq!(m.snapshot().queue_depth, 0);
    });
}

/// A queued request racing `cancel` against `admit` has exactly one
/// winner — never both (double terminal), never neither (lost
/// request) — and the depth gauge returns to zero either way.
#[test]
fn cancel_vs_admit_exactly_one_winner() {
    loom::model(|| {
        let (q, m) = queue(AdmissionConfig::default());
        q.submit(req(5, 2, 1)).expect("fresh queue accepts");
        let qc = Arc::clone(&q);
        let canceller = thread::spawn(move || qc.cancel(5).is_some());
        let admitted = q.admit(0, 0, 0, 8).len();
        let cancelled = canceller.join().unwrap();
        assert!(admitted <= 1);
        assert!(
            (admitted == 1) ^ cancelled,
            "request must be admitted XOR cancelled (admitted={admitted}, cancelled={cancelled})"
        );
        assert_eq!(m.snapshot().queue_depth, 0);
    });
}

/// Pool fan-out: results come back in input order whatever order the
/// two workers dequeue and finish, and pool drop joins cleanly.
/// (Under the real loom crate this model needs its `mpsc` gap closed —
/// see `rust/loom-stub/src/lib.rs`.)
#[test]
fn pool_map_restores_input_order() {
    loom::model(|| {
        let pool = WorkerPool::new(2);
        let out = pool.map(vec![10u64, 20, 30, 40, 50], |i, x| x + i as u64);
        assert_eq!(out, vec![10, 21, 32, 43, 54]);
    });
}

/// Striped cache under concurrent writers on distinct (layer, head)
/// slots: each thread's get-after-put hits its own shard, and the
/// cross-shard stats aggregation stays coherent.
#[test]
fn cache_striped_put_get_is_coherent() {
    loom::model(|| {
        let c = Arc::new(BasisCache::new(2));
        let mut joins = Vec::new();
        for t in 0..2u32 {
            let c = Arc::clone(&c);
            joins.push(thread::spawn(move || {
                let k = CacheKey {
                    model_id: 1,
                    layer: t,
                    head: 0,
                    seq_len: 8,
                    qk_fingerprint: t as u64,
                };
                c.put(k.clone(), dummy_basis(4));
                assert!(c.get(&k).is_some(), "own get-after-put must hit its shard");
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        // Layers 0 and 1 stripe to different shards; nothing evicts.
        assert_eq!(c.stats(), (2, 0, 2), "(hits, misses, len) aggregate across shards");
    });
}
