//! Property-based tests over the crate's core invariants.
//!
//! The image vendors no proptest, so this file carries a minimal
//! in-tree property harness: each property runs across `CASES`
//! independently-seeded random instances with shrink-free reporting
//! (the failing seed is printed — re-run with that seed to reproduce).

use conv_basis::attention::batched::{
    AttnJob, BatchedBackend, BatchedEngine, DecodeJob, DecodeOp, DecodeOutput, EngineConfig,
    EngineJob, EngineResult, JobOutput,
};
use conv_basis::attention::decode::DecodeState;
use conv_basis::attention::rope::rope_structured_qk;
use conv_basis::attention::{
    conv_attention, conv_attention_masked, exact_attention, merge_bases, ExactKernel, Mask,
};
use conv_basis::basis::{
    decompose_exact, exp_transform, recover_from_oracle, ConvBasis, DenseColumnOracle,
    KConvBasis, RecoverConfig,
};
use conv_basis::conv::{conv_apply, conv_apply_naive, sub_conv_apply};
use conv_basis::fft::FftPlanner;
use conv_basis::lowrank::masked;
use conv_basis::tensor::{max_abs_diff, Matrix, Rng};

const CASES: u64 = 40;

/// Prefill-lane submit helper.
fn attend(e: &BatchedEngine, jobs: Vec<AttnJob>) -> Vec<JobOutput> {
    e.submit(jobs.into_iter().enumerate().map(|(i, j)| EngineJob::prefill(i as u64, j)).collect())
        .into_iter()
        .map(|o| o.result.into_prefill())
        .collect()
}

/// Decode-lane submit helper.
fn decode(e: &BatchedEngine, jobs: Vec<DecodeJob>) -> Vec<DecodeOutput> {
    e.submit(jobs.into_iter().enumerate().map(|(i, j)| EngineJob::decode(i as u64, j)).collect())
        .into_iter()
        .map(|o| o.result.into_decode())
        .collect()
}

/// Run `prop(seed)` for many seeds; panic with the seed on failure.
fn for_all(name: &str, prop: impl Fn(u64)) {
    for case in 0..CASES {
        let seed = 0xC0FFEE ^ (case * 2654435761);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(seed)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_default();
            panic!("property `{name}` failed at seed {seed}: {msg}");
        }
    }
}

fn random_dims(rng: &mut Rng) -> (usize, usize) {
    let n = 8 + rng.below(56); // 8..64
    let d = 2 + rng.below(7); // 2..9
    (n, d)
}

#[test]
fn prop_fft_conv_equals_naive() {
    for_all("fft_conv_equals_naive", |seed| {
        let mut rng = Rng::seeded(seed);
        let n = 1 + rng.below(200);
        let a = rng.randn_vec(n);
        let x = rng.randn_vec(n);
        let mut p = FftPlanner::new();
        let fast = conv_apply(&mut p, &a, &x);
        let naive = conv_apply_naive(&a, &x);
        for (u, v) in fast.iter().zip(&naive) {
            assert!((u - v).abs() < 1e-7, "n={n}");
        }
    });
}

#[test]
fn prop_conv_additivity() {
    // Claim 3.8: conv(a)x + conv(b)x == conv(a+b)x.
    for_all("conv_additivity", |seed| {
        let mut rng = Rng::seeded(seed);
        let n = 1 + rng.below(128);
        let a = rng.randn_vec(n);
        let b = rng.randn_vec(n);
        let x = rng.randn_vec(n);
        let mut p = FftPlanner::new();
        let lhs: Vec<f64> = conv_apply(&mut p, &a, &x)
            .iter()
            .zip(conv_apply(&mut p, &b, &x))
            .map(|(u, v)| u + v)
            .collect();
        let sum: Vec<f64> = a.iter().zip(&b).map(|(u, v)| u + v).collect();
        let rhs = conv_apply(&mut p, &sum, &x);
        for (u, v) in lhs.iter().zip(&rhs) {
            assert!((u - v).abs() < 1e-7);
        }
    });
}

#[test]
fn prop_sub_conv_window_consistency() {
    // conv(a, m)·x touches only the last m coordinates, and on them
    // equals the dense sub-conv matvec.
    for_all("sub_conv_window", |seed| {
        let mut rng = Rng::seeded(seed);
        let n = 2 + rng.below(100);
        let m = 1 + rng.below(n);
        let a = rng.randn_vec(n);
        let x = rng.randn_vec(n);
        let mut p = FftPlanner::new();
        let y = sub_conv_apply(&mut p, &a, m, &x);
        for (i, v) in y.iter().enumerate().take(n - m) {
            assert_eq!(*v, 0.0, "leading zero at {i}");
        }
        let dense = conv_basis::conv::SubConvMatrix::new(a, m).to_dense().matvec(&x);
        for (u, v) in y.iter().zip(&dense) {
            assert!((u - v).abs() < 1e-7);
        }
    });
}

#[test]
fn prop_sub_conv_transpose_is_adjoint() {
    // ⟨conv(a,m)·x, y⟩ == ⟨x, conv(a,m)ᵀ·y⟩ — the algebraic property
    // that makes `sub_conv_transpose_apply` the true adjoint of the
    // forward apply (what the conv LM backward's dV/dK chains lean on;
    // until now only covered end-to-end through gradient tests).
    use conv_basis::conv::sub_conv_transpose_apply;
    for_all("sub_conv_transpose_adjoint", |seed| {
        let mut rng = Rng::seeded(seed);
        let n = 2 + rng.below(120);
        let m = 1 + rng.below(n);
        let a = rng.randn_vec(n);
        let x = rng.randn_vec(n);
        let y = rng.randn_vec(n);
        let mut p = FftPlanner::new();
        let fx = sub_conv_apply(&mut p, &a, m, &x);
        let fty = sub_conv_transpose_apply(&mut p, &a, m, &y);
        let lhs: f64 = fx.iter().zip(&y).map(|(u, v)| u * v).sum();
        let rhs: f64 = x.iter().zip(&fty).map(|(u, v)| u * v).sum();
        // FFT round-off scales with the inner products' magnitude.
        let scale = 1.0 + lhs.abs().max(rhs.abs());
        assert!(
            (lhs - rhs).abs() < 1e-7 * scale,
            "n={n} m={m}: ⟨f·x, y⟩ = {lhs} vs ⟨x, fᵀ·y⟩ = {rhs}"
        );

        // The k-conv composite inherits adjointness term by term.
        let k = 1 + rng.below(3);
        let mut ms: Vec<usize> = (0..k).map(|_| 1 + rng.below(n)).collect();
        ms.sort_unstable();
        ms.dedup();
        ms.reverse();
        let basis = KConvBasis::new(
            n,
            ms.iter().map(|&m| ConvBasis { b: rng.randn_vec(n), m }).collect(),
        );
        let bx = basis.apply(&mut p, &x);
        let bty = basis.apply_transpose(&mut p, &y);
        let lhs: f64 = bx.iter().zip(&y).map(|(u, v)| u * v).sum();
        let rhs: f64 = x.iter().zip(&bty).map(|(u, v)| u * v).sum();
        let scale = 1.0 + lhs.abs().max(rhs.abs());
        assert!(
            (lhs - rhs).abs() < 1e-7 * scale,
            "k-conv n={n}: ⟨B·x, y⟩ = {lhs} vs ⟨x, Bᵀ·y⟩ = {rhs}"
        );
    });
}

#[test]
fn prop_decompose_roundtrip() {
    // Lemma 3.12: decompose_exact ∘ to_dense == identity on k-conv
    // matrices, with minimal k.
    for_all("decompose_roundtrip", |seed| {
        let mut rng = Rng::seeded(seed);
        let n = 6 + rng.below(40);
        let k = 1 + rng.below(4.min(n));
        // Distinct decreasing windows.
        let mut ms: Vec<usize> = Vec::new();
        let mut m = n;
        for _ in 0..k {
            ms.push(m);
            if m <= 2 {
                break;
            }
            m = 1 + rng.below(m - 1);
        }
        let terms: Vec<ConvBasis> = ms
            .iter()
            .map(|&m| {
                let mut b = rng.randn_vec(n);
                for t in b.iter_mut().skip(m) {
                    *t = 0.0;
                }
                // Ensure the onset column actually differs (b ≠ 0 head).
                b[0] += 1.0;
                ConvBasis { b, m }
            })
            .collect();
        let basis = KConvBasis::new(n, terms);
        let h = basis.to_dense();
        let rec = decompose_exact(&h, 1e-9);
        assert_eq!(rec.k(), ms.len(), "minimal k");
        assert!(max_abs_diff(&rec.to_dense(), &h) < 1e-8);
    });
}

#[test]
fn prop_recover_roundtrip_nondegenerate() {
    // Algorithm 2 recovers any (T, δ)-non-degenerate basis exactly.
    for_all("recover_roundtrip", |seed| {
        let mut rng = Rng::seeded(seed);
        let n = 16 + rng.below(64);
        let t = 2 + rng.below(3);
        let k = 1 + rng.below(3);
        let mut ms = vec![n];
        for _ in 1..k {
            let last = *ms.last().unwrap();
            if last <= t + 1 {
                break;
            }
            ms.push(t + 1 + rng.below(last - t - 1));
        }
        let terms: Vec<ConvBasis> = ms
            .iter()
            .map(|&m| {
                let mut b = rng.randn_vec(n);
                for x in b.iter_mut().take(t) {
                    *x = 1.0 + rng.uniform(); // positive window head
                }
                for x in b.iter_mut().skip(m) {
                    *x = 0.0;
                }
                ConvBasis { b, m }
            })
            .collect();
        let basis = KConvBasis::new(n, terms);
        let h = basis.to_dense();
        let cfg = RecoverConfig { k_max: 8, t, delta: 0.5, eps: 1e-9 };
        let (rec, _) = recover_from_oracle(&DenseColumnOracle(&h), &cfg).unwrap();
        assert_eq!(rec.k(), ms.len());
        assert!(max_abs_diff(&rec.to_dense(), &h) < 1e-8);
    });
}

#[test]
fn prop_exp_transform_is_masked_exp() {
    // Lemma B.16 (+ completion): compose(exp_transform(B)) ==
    // causal ∘ exp(compose(B)).
    for_all("exp_transform", |seed| {
        let mut rng = Rng::seeded(seed);
        let n = 4 + rng.below(32);
        let k = 1 + rng.below(3);
        let mut ms: Vec<usize> = Vec::new();
        let mut m = 1 + rng.below(n);
        for _ in 0..k {
            ms.push(m);
            if m <= 1 {
                break;
            }
            m = 1 + rng.below(m - 1);
        }
        ms.dedup();
        let terms: Vec<ConvBasis> = ms
            .iter()
            .map(|&m| ConvBasis { b: rng.randn_vec(n).iter().map(|x| x * 0.5).collect(), m })
            .collect();
        let basis = KConvBasis::new(n, terms);
        let want = Mask::causal(n).apply(&basis.to_dense().map(f64::exp));
        let got = exp_transform(&basis, true).to_dense();
        assert!(max_abs_diff(&want, &got) < 1e-9);
    });
}

#[test]
fn prop_conv_attention_error_bound() {
    // Theorem 4.4 on exactly-structured inputs: error ≈ 0; on ε-noised
    // inputs: within the theorem bound.
    for_all("conv_attention_bound", |seed| {
        let mut rng = Rng::seeded(seed);
        let n = 24 + rng.below(40);
        let d = 4 + 2 * rng.below(3);
        let (q, k) = conv_basis::attention::rope::rope_structured_qk(n, d, 2, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let exact = exact_attention(&q, &k, &v, &Mask::causal(n));
        let t = 3;
        let cfg = RecoverConfig { k_max: 4, t, delta: 5.0 * t as f64 * 1e-8, eps: 1e-8 };
        let out = conv_attention(&q, &k, &v, &cfg).unwrap();
        let err = max_abs_diff(&exact, &out.y);
        assert!(err < 1e-7, "err = {err}");
    });
}

#[test]
fn prop_masked_lowrank_kernels_match_dense() {
    for_all("masked_lowrank", |seed| {
        let mut rng = Rng::seeded(seed);
        let (n, kdim) = random_dims(&mut rng);
        let u1 = Matrix::randn(n, kdim, &mut rng);
        let u2 = Matrix::randn(n, kdim, &mut rng);
        let v = rng.randn_vec(n);
        // Causal.
        let causal = Mask::causal(n);
        let want = masked::dense_multiply(&causal, &u1, &u2, &v);
        let got = masked::causal_multiply(&u1, &u2, &v);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-8);
        }
        // Sliding window via deltas.
        let w = 1 + rng.below(n);
        let sw = Mask::sliding_window(n, w, rng.below(3));
        let want = masked::dense_multiply(&sw, &u1, &u2, &v);
        let got = masked::row_change_multiply(&sw, &u1, &u2, &v);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-8);
        }
        // Continuous rows, segment tree and prefix agree with dense.
        let s: Vec<usize> = (0..n).map(|i| rng.below(i + 1)).collect();
        let t: Vec<usize> = (0..n).map(|i| s[i] + rng.below(n - s[i])).collect();
        let cr = Mask::continuous_row(s.clone(), t.clone());
        let want = masked::dense_multiply(&cr, &u1, &u2, &v);
        for got in [
            masked::continuous_row_multiply_segtree(&u1, &u2, &v, &s, &t),
            masked::continuous_row_multiply_prefix(&u1, &u2, &v, &s, &t),
        ] {
            for (a, b) in want.iter().zip(&got) {
                assert!((a - b).abs() < 1e-8);
            }
        }
    });
}

#[test]
fn prop_merge_bases_is_sum() {
    for_all("merge_bases", |seed| {
        let mut rng = Rng::seeded(seed);
        let n = 4 + rng.below(24);
        let mk = |rng: &mut Rng| {
            let k = 1 + rng.below(3);
            let mut ms: Vec<usize> = (0..k).map(|_| 1 + rng.below(n)).collect();
            ms.sort_unstable();
            ms.dedup();
            ms.reverse();
            KConvBasis::new(
                n,
                ms.iter().map(|&m| ConvBasis { b: rng.randn_vec(n), m }).collect(),
            )
        };
        let a = mk(&mut rng);
        let b = mk(&mut rng);
        let merged = merge_bases(&a, &b);
        let want = a.to_dense().add(&b.to_dense());
        assert!(max_abs_diff(&merged.to_dense(), &want) < 1e-9);
    });
}

#[test]
fn prop_gradient_fast_matches_naive() {
    for_all("gradient_fast", |seed| {
        let mut rng = Rng::seeded(seed);
        let n = 10 + rng.below(16);
        let d = 2 + rng.below(3);
        let p = conv_basis::gradient::AttentionLossProblem::random_structured(n, d, &mut rng);
        let x = Matrix::randn(d, d, &mut rng).scale(0.3);
        let g_naive = conv_basis::gradient::grad_naive(&p, &x);
        let (g_fast, _) =
            conv_basis::gradient::grad_fast(&p, &x, &RecoverConfig::exact(n)).unwrap();
        assert!(max_abs_diff(&g_naive, &g_fast) < 1e-7);
    });
}

#[test]
fn prop_batched_matches_single() {
    // The batched engine must reproduce the per-sequence
    // `conv_attention_masked` output to 1e-10 across random seeds,
    // masks, and head counts (it runs the identical operator, so the
    // agreement is in fact bit-exact; 1e-10 is the contract).
    let engine = BatchedEngine::new(EngineConfig { workers: 3, cache_capacity: 128 });
    for_all("batched_matches_single", |seed| {
        let mut rng = Rng::seeded(seed);
        let n = 8 + rng.below(24); // 8..32
        let d = 2 + rng.below(5); // 2..7
        let heads = 1 + rng.below(4); // 1..4
        let mask = match rng.below(3) {
            0 => Mask::causal(n),
            1 => Mask::sliding_window(n, 1 + rng.below(n), rng.below(3)),
            _ => {
                // Random lower-triangular mask with a full diagonal (so
                // every row keeps a non-empty softmax support).
                let mut bits = vec![false; n * n];
                for i in 0..n {
                    for j in 0..=i {
                        bits[i * n + j] = j == i || rng.below(4) != 0;
                    }
                }
                Mask::dense(n, bits)
            }
        };
        let cfg = RecoverConfig::exact(n);
        let mut jobs = Vec::new();
        let mut singles = Vec::new();
        for h in 0..heads {
            let q = Matrix::randn(n, d, &mut rng).scale(0.3);
            let k = Matrix::randn(n, d, &mut rng).scale(0.3);
            let v = Matrix::randn(n, d, &mut rng);
            singles.push(conv_attention_masked(&q, &k, &v, &mask, &cfg).unwrap().y);
            jobs.push(AttnJob {
                layer: 0,
                head: h as u32,
                q,
                k,
                v,
                mask: Some(mask.clone()),
                backend: BatchedBackend::Conv(cfg),
                training: false,
            });
        }
        let outs = attend(&engine, jobs);
        assert_eq!(outs.len(), singles.len());
        for (out, want) in outs.iter().zip(&singles) {
            assert!(!out.fell_back, "exact-config recovery cannot fail");
            let err = max_abs_diff(&out.y, want);
            assert!(err < 1e-10, "batched vs single err = {err}");
        }
    });
}

#[test]
fn prop_batched_deterministic_across_thread_counts() {
    // Same jobs on pools of 1, 2 and 8 workers must give bit-identical
    // results: jobs are pure and the pool restores input order.
    let engines: Vec<BatchedEngine> = [1usize, 2, 8]
        .iter()
        .map(|&w| BatchedEngine::new(EngineConfig { workers: w, cache_capacity: 128 }))
        .collect();
    for seed in [11u64, 22, 33] {
        let mut rng = Rng::seeded(seed);
        let (n, d) = (48, 8);
        let mut jobs = Vec::new();
        for h in 0..4u32 {
            let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
            let v = Matrix::randn(n, d, &mut rng);
            let backend = match h % 3 {
                0 => BatchedBackend::Exact(ExactKernel::RowStream),
                1 => BatchedBackend::Strided(4),
                _ => BatchedBackend::Conv(RecoverConfig::exact(n)),
            };
            jobs.push(AttnJob { layer: 0, head: h, q, k, v, mask: None, backend, training: false });
        }
        let base = attend(&engines[0], jobs.clone());
        for e in &engines[1..] {
            let outs = attend(e, jobs.clone());
            for (a, b) in outs.iter().zip(&base) {
                assert_eq!(
                    max_abs_diff(&a.y, &b.y),
                    0.0,
                    "thread count changed the output (seed {seed})"
                );
            }
        }
    }
}

#[test]
fn prop_decode_batch_deterministic() {
    // Decode jobs (mixed exact + conv, several heads) on pools of 1, 2
    // and 8 workers must give bit-identical outputs — decode steps are
    // pure and the pool restores input order, exactly like the prefill
    // path.
    let engines: Vec<BatchedEngine> = [1usize, 2, 8]
        .iter()
        .map(|&w| BatchedEngine::new(EngineConfig { workers: w, cache_capacity: 128 }))
        .collect();
    for seed in [51u64, 52, 53] {
        let mk_jobs = || -> Vec<DecodeJob> {
            let mut rng = conv_basis::tensor::Rng::seeded(seed);
            let (n, d) = (24, 4);
            (0..6u32)
                .map(|h| {
                    let (q_full, k_full) = rope_structured_qk(n + 1, d, 2, &mut rng);
                    let q = q_full.slice(0, n, 0, d);
                    let k = k_full.slice(0, n, 0, d);
                    let new_row: Vec<f64> = (0..=n)
                        .map(|j| conv_basis::tensor::dot(q_full.row(n), k_full.row(j)))
                        .collect();
                    let v = Matrix::randn(n + 1, d, &mut rng);
                    if h % 2 == 0 {
                        DecodeJob {
                            layer: 0,
                            head: h,
                            state: None,
                            new_row,
                            v,
                            q: None,
                            k: None,
                            op: DecodeOp::Exact(ExactKernel::RowStream),
                        }
                    } else {
                        let zeros = Matrix::zeros(n, d);
                        let out = conv_basis::attention::conv_attention_strided(&q, &k, &zeros, 1)
                            .unwrap();
                        DecodeJob {
                            layer: 0,
                            head: h,
                            state: Some(DecodeState::new(out.post_basis, out.d_tilde)),
                            new_row,
                            v,
                            q: Some(q_full),
                            k: Some(k_full),
                            op: DecodeOp::conv(1),
                        }
                    }
                })
                .collect()
        };
        let base = decode(&engines[0], mk_jobs());
        for e in &engines[1..] {
            let outs = decode(e, mk_jobs());
            for (a, b) in outs.iter().zip(&base) {
                assert_eq!(a.y_last, b.y_last, "worker count changed decode (seed {seed})");
            }
        }
    }
}

#[test]
fn prop_batched_grad_matches_single() {
    // The engine's gradient lane must be bit-identical to per-problem
    // `grad_fast`, for worker counts 1, 2 and 8 — the training-side
    // mirror of `prop_batched_matches_single`.
    use conv_basis::gradient::batched::{FastGradConfig, GradJob};
    use conv_basis::gradient::{grad_fast, AttentionLossProblem};
    let engines: Vec<BatchedEngine> = [1usize, 2, 8]
        .iter()
        .map(|&w| BatchedEngine::new(EngineConfig { workers: w, cache_capacity: 128 }))
        .collect();
    for seed in [61u64, 62, 63] {
        let mk_jobs = || -> Vec<GradJob> {
            let mut rng = Rng::seeded(seed);
            (0..5u32)
                .map(|i| {
                    let n = 12 + 4 * i as usize;
                    let d = 3;
                    let problem =
                        std::sync::Arc::new(AttentionLossProblem::random_structured(n, d, &mut rng));
                    let x = Matrix::randn(d, d, &mut rng).scale(0.3);
                    GradJob { layer: i, head: 0, problem, x, cfg: FastGradConfig::exact(n) }
                })
                .collect()
        };
        let singles: Vec<(Matrix, f64)> = mk_jobs()
            .iter()
            .map(|j| {
                let (g, r) = grad_fast(&j.problem, &j.x, &j.cfg.recover).unwrap();
                (g, r.loss)
            })
            .collect();
        for e in &engines {
            let outs = e.submit(
                mk_jobs()
                    .into_iter()
                    .enumerate()
                    .map(|(i, j)| EngineJob::gradient(i as u64, j))
                    .collect(),
            );
            for (out, (g, loss)) in outs.iter().zip(&singles) {
                let EngineResult::Gradient(got) = &out.result else {
                    panic!("gradient job must return a gradient result")
                };
                assert!(!got.fell_back, "exact-config recovery cannot fail (seed {seed})");
                assert_eq!(
                    max_abs_diff(&got.grad, g),
                    0.0,
                    "batched grad must bit-match grad_fast (seed {seed}, {} workers)",
                    e.workers()
                );
                assert_eq!(got.loss, *loss, "seed {seed}");
            }
        }
    }
}

#[test]
fn prop_submit_mixed_lanes_deterministic() {
    // The ISSUE 3 acceptance property: ONE submit carrying prefill,
    // decode AND gradient jobs returns input-ordered, key-echoed
    // results that are bit-identical across worker counts 1/2/8 and
    // bit-identical to each lane's single-problem oracle.
    use conv_basis::gradient::batched::{FastGradConfig, GradJob};
    use conv_basis::gradient::{grad_fast, AttentionLossProblem};
    let mk_jobs = || -> Vec<EngineJob> {
        let mut rng = Rng::seeded(0x3155);
        let mut jobs = Vec::new();
        for i in 0..2u32 {
            // Prefill lane: strided conv over structured Q/K.
            let (n, d) = (40, 8);
            let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
            let v = Matrix::randn(n, d, &mut rng);
            jobs.push(EngineJob::prefill(
                (100 + i) as u64,
                AttnJob::causal(0, i, q, k, v, BatchedBackend::Strided(4)),
            ));
            // Decode lane: one exact step on a grown sequence.
            let (nd, dd) = (24, 4);
            let (q_full, k_full) = rope_structured_qk(nd + 1, dd, 2, &mut rng);
            let new_row: Vec<f64> = (0..=nd)
                .map(|j| conv_basis::tensor::dot(q_full.row(nd), k_full.row(j)))
                .collect();
            jobs.push(EngineJob::decode(
                (200 + i) as u64,
                DecodeJob {
                    layer: 1,
                    head: i,
                    state: None,
                    new_row,
                    v: Matrix::randn(nd + 1, dd, &mut rng),
                    q: None,
                    k: None,
                    op: DecodeOp::Exact(ExactKernel::RowStream),
                },
            ));
            // Gradient lane: Definition 5.1 backward.
            let ng = 16;
            let problem =
                std::sync::Arc::new(AttentionLossProblem::random_structured(ng, 3, &mut rng));
            let x = Matrix::randn(3, 3, &mut rng).scale(0.3);
            jobs.push(EngineJob::gradient(
                (300 + i) as u64,
                GradJob { layer: 2, head: i, problem, x, cfg: FastGradConfig::exact(ng) },
            ));
        }
        jobs
    };
    let keys: Vec<u64> = vec![100, 200, 300, 101, 201, 301];
    // Lane oracles from the single-problem paths.
    let oracle_jobs = mk_jobs();
    let mut oracle_y = Vec::new();
    let mut oracle_rows = Vec::new();
    let mut oracle_grads = Vec::new();
    for j in &oracle_jobs {
        match &j.op {
            conv_basis::attention::batched::EngineOp::Prefill(a) => oracle_y.push(
                conv_basis::attention::conv_attention_strided(&a.q, &a.k, &a.v, 4).unwrap().y,
            ),
            conv_basis::attention::batched::EngineOp::Decode(dj) => oracle_rows.push(
                conv_basis::attention::decode::exact_decode_last_row(&dj.new_row, &dj.v),
            ),
            conv_basis::attention::batched::EngineOp::Gradient(g) => {
                oracle_grads.push(grad_fast(&g.problem, &g.x, &g.cfg.recover).unwrap().0)
            }
            other => panic!("unexpected lane in this batch: {}", other.lane()),
        }
    }
    let mut per_worker: Vec<Vec<conv_basis::attention::batched::EngineOutput>> = Vec::new();
    for workers in [1usize, 2, 8] {
        let e = BatchedEngine::new(EngineConfig { workers, cache_capacity: 64 });
        let outs = e.submit(mk_jobs());
        assert_eq!(outs.iter().map(|o| o.key).collect::<Vec<_>>(), keys, "key echo + order");
        let snap = e.metrics().snapshot();
        assert_eq!(snap.submit_calls, 1);
        assert_eq!((snap.batched_jobs, snap.decode_steps, snap.grad_jobs), (2, 2, 2));
        per_worker.push(outs);
    }
    // Bit-identical across worker counts and vs the lane oracles.
    for outs in &per_worker {
        let (mut iy, mut ir, mut ig) = (0usize, 0usize, 0usize);
        for out in outs {
            match &out.result {
                EngineResult::Prefill(p) => {
                    assert!(!p.fell_back);
                    assert_eq!(max_abs_diff(&p.y, &oracle_y[iy]), 0.0, "prefill lane");
                    iy += 1;
                }
                EngineResult::Decode(dv) => {
                    assert_eq!(dv.y_last, oracle_rows[ir], "decode lane");
                    ir += 1;
                }
                EngineResult::Gradient(g) => {
                    assert_eq!(max_abs_diff(&g.grad, &oracle_grads[ig]), 0.0, "gradient lane");
                    ig += 1;
                }
                other => panic!("unexpected lane in this batch: {}", other.lane()),
            }
        }
        assert_eq!((iy, ir, ig), (2, 2, 2), "every lane fully represented");
    }
}

#[test]
fn prop_submit_fuzzed_mixed_lanes_bit_identical_across_worker_counts() {
    // The ISSUE 4 fuzz pin, extended for ISSUEs 5, 7, 9 and 10: a
    // deterministic-seed generator builds random batches mixing ALL
    // FOUR lanes — Prefill (serving, conv-forward *training*, the
    // speculative-decoding verify submits built by `AttnJob::verify`,
    // AND router-resolved `BatchedBackend::Routed` jobs with
    // randomized per-head tables) + Decode + Gradient + the
    // LM-backward jobs (with and without a forward-provided basis
    // handle) — with random sizes and modes, and every seed must
    // produce input-ordered, key-echoed results that are
    // bit-identical across worker counts 1/2/8, training artifacts
    // (probs / basis handles) included. ISSUE 10 adds a ninth arm
    // mixing the flash-style blocked exact kernels (serving prefill,
    // training prefill, decode, LM backward) into the same batches.
    use conv_basis::coordinator::CachedBasis;
    use conv_basis::gradient::batched::{
        AttnBackwardJob, AttnBackwardMode, FastGradConfig, GradJob,
    };
    use conv_basis::gradient::AttentionLossProblem;
    use conv_basis::tensor::softmax;
    use std::sync::Arc;

    /// Dense causal softmax rows with the training forward's float-op
    /// order (what the exact LM-backward mode consumes).
    fn causal_probs(q: &Matrix, k: &Matrix) -> Matrix {
        let n = q.rows();
        let logits = q.matmul(&k.transpose());
        let mut probs = Matrix::zeros(n, n);
        for i in 0..n {
            let row = softmax(&logits.row(i)[..=i]);
            probs.row_mut(i)[..=i].copy_from_slice(&row);
        }
        probs
    }

    let mk_jobs = |seed: u64| -> Vec<EngineJob> {
        let mut rng = Rng::seeded(seed);
        let count = 6 + rng.below(8); // 6..14 jobs
        let mut jobs = Vec::with_capacity(count);
        for idx in 0..count {
            let key = 1000 + idx as u64;
            match rng.below(9) {
                0 => {
                    // Prefill: random size, exact or strided operator.
                    let n = 12 + rng.below(28);
                    let d = 2 + 2 * rng.below(3);
                    let (q, k) = rope_structured_qk(n, d, 2, &mut rng);
                    let v = Matrix::randn(n, d, &mut rng);
                    let backend = if rng.below(2) == 0 {
                        BatchedBackend::Exact(ExactKernel::RowStream)
                    } else {
                        BatchedBackend::Strided(1 + rng.below(4))
                    };
                    jobs.push(EngineJob::prefill(
                        key,
                        AttnJob::causal(0, idx as u32, q, k, v, backend),
                    ));
                }
                1 => {
                    // Decode: one exact step on a random-length prefix.
                    let n = 8 + rng.below(24);
                    let d = 2 + rng.below(4);
                    let q = Matrix::randn(n + 1, d, &mut rng).scale(0.3);
                    let k = Matrix::randn(n + 1, d, &mut rng).scale(0.3);
                    let new_row: Vec<f64> = (0..=n)
                        .map(|j| conv_basis::tensor::dot(q.row(n), k.row(j)))
                        .collect();
                    jobs.push(EngineJob::decode(
                        key,
                        DecodeJob {
                            layer: 1,
                            head: idx as u32,
                            state: None,
                            new_row,
                            v: Matrix::randn(n + 1, d, &mut rng),
                            q: None,
                            k: None,
                            op: DecodeOp::Exact(ExactKernel::RowStream),
                        },
                    ));
                }
                2 => {
                    // Gradient: Definition 5.1 backward, random size.
                    let n = 10 + rng.below(14);
                    let problem = std::sync::Arc::new(AttentionLossProblem::random_structured(
                        n, 3, &mut rng,
                    ));
                    let x = Matrix::randn(3, 3, &mut rng).scale(0.3);
                    jobs.push(EngineJob::gradient(
                        key,
                        GradJob {
                            layer: 2,
                            head: idx as u32,
                            problem,
                            x,
                            cfg: FastGradConfig::exact(n),
                        },
                    ));
                }
                3 => {
                    // LM backward: exact and fast modes both in the mix.
                    let n = 8 + rng.below(20);
                    let dh = 2 + rng.below(3);
                    let q = Matrix::randn(n, dh, &mut rng).scale(0.3);
                    let k = Matrix::randn(n, dh, &mut rng).scale(0.3);
                    let probs = Arc::new(causal_probs(&q, &k));
                    let mode = if rng.below(2) == 0 {
                        AttnBackwardMode::Exact(ExactKernel::RowStream)
                    } else {
                        AttnBackwardMode::Fast(FastGradConfig::exact(n))
                    };
                    jobs.push(EngineJob::attn_backward(
                        key,
                        AttnBackwardJob {
                            layer: 3,
                            head: idx as u32,
                            q,
                            k,
                            v: Matrix::randn(n, dh, &mut rng),
                            dout: Matrix::randn(n, dh, &mut rng),
                            probs: Some(probs),
                            basis: None,
                            mode,
                        },
                    ));
                }
                4 => {
                    // Conv-forward TRAINING prefill (the step-scoped
                    // basis flow): exact-budget recovery returns a
                    // basis handle; a 1-in-3 hostile budget exercises
                    // the bit-exact fallback artifact (probs) instead.
                    let n = 10 + rng.below(22);
                    let d = 2 + rng.below(4);
                    let q = Matrix::randn(n, d, &mut rng).scale(0.3);
                    let k = Matrix::randn(n, d, &mut rng).scale(0.3);
                    let v = Matrix::randn(n, d, &mut rng);
                    let cfg = if rng.below(3) == 0 {
                        RecoverConfig { k_max: 0, t: 1, delta: 1.0, eps: 0.0 }
                    } else {
                        RecoverConfig::exact(n)
                    };
                    jobs.push(EngineJob::prefill(
                        key,
                        AttnJob::causal(4, idx as u32, q, k, v, BatchedBackend::Conv(cfg))
                            .for_training(),
                    ));
                }
                5 => {
                    // Speculative-decoding VERIFY submit: the exact
                    // batched forward the generation scheduler uses to
                    // check drafted tokens, mixed into a random batch.
                    // It must stay a plain exact prefill job — pure,
                    // worker-count-independent, and inert next to every
                    // other lane (the scheduler relies on row
                    // independence of exactly this output).
                    let n = 12 + rng.below(24);
                    let d = 2 + 2 * rng.below(3);
                    let (q, k) = rope_structured_qk(n, d, 2, &mut rng);
                    let v = Matrix::randn(n, d, &mut rng);
                    jobs.push(EngineJob::prefill(key, AttnJob::verify(6, idx as u32, q, k, v)));
                }
                6 => {
                    // Fast LM backward CONSUMING a step-basis handle —
                    // the forward→backward handoff as a standalone job.
                    let n = 10 + rng.below(18);
                    let dh = 2 + rng.below(3);
                    let (q_full, k_full) = rope_structured_qk(n, dh, 2, &mut rng);
                    let v = Matrix::randn(n, dh, &mut rng);
                    let kb = 1 + rng.below(3);
                    let out =
                        conv_basis::attention::conv_attention_strided(&q_full, &k_full, &v, kb)
                            .unwrap();
                    let handle =
                        Arc::new(CachedBasis { post_basis: out.post_basis, d_tilde: out.d_tilde });
                    jobs.push(EngineJob::attn_backward(
                        key,
                        AttnBackwardJob {
                            layer: 5,
                            head: idx as u32,
                            q: q_full,
                            k: k_full,
                            v,
                            dout: Matrix::randn(n, dh, &mut rng),
                            probs: None,
                            basis: Some(handle),
                            mode: AttnBackwardMode::Fast(FastGradConfig {
                                recover: RecoverConfig::exact(n),
                                use_cache: false,
                            }),
                        },
                    ));
                }
                7 => {
                    // ROUTED prefill (the ISSUE 9 adaptive router): a
                    // randomized per-head policy table resolves to one
                    // of the direct operators *inside* job execution,
                    // so routed jobs must stay exactly as pure,
                    // order-preserving and worker-count-independent as
                    // the arms above — and inert next to every other
                    // lane.
                    use conv_basis::attention::batched::{HeadRoute, RouterPolicy};
                    use conv_basis::lowrank::LowRankConfig;
                    let n = 16 + rng.below(24);
                    let d = 2 + 2 * rng.below(2);
                    let (q, k) = rope_structured_qk(n, d, 2, &mut rng);
                    let v = Matrix::randn(n, d, &mut rng);
                    let route = match rng.below(4) {
                        0 => HeadRoute::Exact,
                        1 => HeadRoute::Strided(1 + rng.below(4)),
                        2 => HeadRoute::Conv(RecoverConfig::exact(n)),
                        _ => HeadRoute::LowRank(LowRankConfig::new(1 + rng.below(2), 1.0)),
                    };
                    let policy = Arc::new(
                        RouterPolicy::new(HeadRoute::Exact)
                            .set(7, idx as u32, route)
                            .with_lowrank_fallback(HeadRoute::Strided(2)),
                    );
                    jobs.push(EngineJob::prefill(
                        key,
                        AttnJob::causal(7, idx as u32, q, k, v, BatchedBackend::Routed(policy)),
                    ));
                }
                _ => {
                    // BLOCKED exact lanes (ISSUE 10): the flash-style
                    // tiled kernels behind `ExactKernel::Blocked`,
                    // mixed into random batches as serving prefill,
                    // training prefill (probs artifact), decode step,
                    // and LM backward. Rows are independent in every
                    // one of them, so they must stay exactly as pure
                    // and worker-count-independent as the row-stream
                    // arms above.
                    let n = 10 + rng.below(40);
                    let d = 2 + rng.below(4);
                    let q = Matrix::randn(n, d, &mut rng).scale(0.3);
                    let k = Matrix::randn(n, d, &mut rng).scale(0.3);
                    let v = Matrix::randn(n, d, &mut rng);
                    let blocked = BatchedBackend::Exact(ExactKernel::Blocked);
                    match rng.below(4) {
                        0 => jobs.push(EngineJob::prefill(
                            key,
                            AttnJob::causal(8, idx as u32, q, k, v, blocked),
                        )),
                        1 => jobs.push(EngineJob::prefill(
                            key,
                            AttnJob::causal(8, idx as u32, q, k, v, blocked).for_training(),
                        )),
                        2 => {
                            let new_row: Vec<f64> = (0..n)
                                .map(|j| conv_basis::tensor::dot(q.row(n - 1), k.row(j)))
                                .collect();
                            jobs.push(EngineJob::decode(
                                key,
                                DecodeJob {
                                    layer: 8,
                                    head: idx as u32,
                                    state: None,
                                    new_row,
                                    v,
                                    q: None,
                                    k: None,
                                    op: DecodeOp::Exact(ExactKernel::Blocked),
                                },
                            ));
                        }
                        _ => {
                            let probs = Arc::new(causal_probs(&q, &k));
                            jobs.push(EngineJob::attn_backward(
                                key,
                                AttnBackwardJob {
                                    layer: 8,
                                    head: idx as u32,
                                    q,
                                    k,
                                    v,
                                    dout: Matrix::randn(n, d, &mut rng),
                                    probs: Some(probs),
                                    basis: None,
                                    mode: AttnBackwardMode::Exact(ExactKernel::Blocked),
                                },
                            ));
                        }
                    }
                }
            }
        }
        jobs
    };

    for seed in [0x51u64, 0x52, 0x53, 0x54, 0x55] {
        let keys: Vec<u64> = mk_jobs(seed).iter().map(|j| j.key).collect();
        let mut per_worker: Vec<Vec<conv_basis::attention::batched::EngineOutput>> = Vec::new();
        for workers in [1usize, 2, 8] {
            let e = BatchedEngine::new(EngineConfig { workers, cache_capacity: 64 });
            let outs = e.submit(mk_jobs(seed));
            assert_eq!(
                outs.iter().map(|o| o.key).collect::<Vec<_>>(),
                keys,
                "seed {seed}: input order + key echo ({workers} workers)"
            );
            per_worker.push(outs);
        }
        let base = &per_worker[0];
        for (outs, workers) in per_worker[1..].iter().zip([2usize, 8]) {
            for (a, b) in outs.iter().zip(base) {
                match (&a.result, &b.result) {
                    (EngineResult::Prefill(x), EngineResult::Prefill(y)) => {
                        assert_eq!(
                            max_abs_diff(&x.y, &y.y),
                            0.0,
                            "seed {seed}: prefill bits ({workers} workers)"
                        );
                        // Training artifacts are part of the contract:
                        // same presence, same bits, per worker count.
                        assert_eq!(x.fell_back, y.fell_back, "seed {seed}: fallback flip");
                        match (&x.probs, &y.probs) {
                            (None, None) => {}
                            (Some(px), Some(py)) => assert_eq!(
                                max_abs_diff(px, py),
                                0.0,
                                "seed {seed}: training probs bits ({workers} workers)"
                            ),
                            _ => panic!("seed {seed}: probs presence flip ({workers} workers)"),
                        }
                        match (&x.basis, &y.basis) {
                            (None, None) => {}
                            (Some(bx), Some(by)) => {
                                assert_eq!(
                                    bx.d_tilde, by.d_tilde,
                                    "seed {seed}: handle normalizer bits ({workers} workers)"
                                );
                                let (da, db) =
                                    (bx.post_basis.to_dense(), by.post_basis.to_dense());
                                assert_eq!(
                                    max_abs_diff(&da, &db),
                                    0.0,
                                    "seed {seed}: handle basis bits ({workers} workers)"
                                );
                            }
                            _ => panic!("seed {seed}: basis presence flip ({workers} workers)"),
                        }
                    }
                    (EngineResult::Decode(x), EngineResult::Decode(y)) => {
                        assert_eq!(
                            x.y_last, y.y_last,
                            "seed {seed}: decode bits ({workers} workers)"
                        );
                    }
                    (EngineResult::Gradient(x), EngineResult::Gradient(y)) => {
                        assert_eq!(
                            max_abs_diff(&x.grad, &y.grad),
                            0.0,
                            "seed {seed}: gradient bits ({workers} workers)"
                        );
                        assert_eq!(x.loss, y.loss, "seed {seed}");
                    }
                    (EngineResult::AttnBackward(x), EngineResult::AttnBackward(y)) => {
                        assert!(!x.fell_back, "seed {seed}: exact-config recovery cannot fail");
                        for (gx, gy, name) in
                            [(&x.dq, &y.dq, "dq"), (&x.dk, &y.dk, "dk"), (&x.dv, &y.dv, "dv")]
                        {
                            assert_eq!(
                                max_abs_diff(gx, gy),
                                0.0,
                                "seed {seed}: lm-backward {name} bits ({workers} workers)"
                            );
                        }
                    }
                    (a, b) => panic!(
                        "seed {seed}: lane flip — {} vs {} ({workers} workers)",
                        a.lane(),
                        b.lane()
                    ),
                }
            }
        }
    }
}

#[test]
fn prop_row_sums_match_apply_ones() {
    for_all("row_sums", |seed| {
        let mut rng = Rng::seeded(seed);
        let n = 4 + rng.below(48);
        let k = 1 + rng.below(3);
        let mut ms: Vec<usize> = (0..k).map(|_| 1 + rng.below(n)).collect();
        ms.sort_unstable();
        ms.dedup();
        ms.reverse();
        let basis = KConvBasis::new(
            n,
            ms.iter().map(|&m| ConvBasis { b: rng.randn_vec(n), m }).collect(),
        );
        let mut p = FftPlanner::new();
        let via_fft = basis.apply(&mut p, &vec![1.0; n]);
        let closed = basis.row_sums();
        for (a, b) in via_fft.iter().zip(&closed) {
            assert!((a - b).abs() < 1e-7);
        }
    });
}
