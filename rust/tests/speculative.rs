//! Speculative decoding: the greedy-equivalence oracle harness.
//!
//! The contract under test (ISSUE PR 7): with `GenConfig::speculate =
//! γ`, each scheduler round drafts γ tokens per flight through the
//! cheap serving decode path, verifies all of them plus one bonus
//! position in a single exact prefill-lane engine submit, and keeps the
//! longest accepted prefix. Because verification is **exact** and
//! decoding is greedy argmax, the emitted stream must be bit-identical
//! to non-speculative exact-greedy decoding — for every γ, every
//! worker count, and *any* draft backend (a broken drafter costs
//! acceptance rate, never correctness). γ = 0 must be the identity:
//! the plain pre-speculation scheduler path, counter for counter.

use conv_basis::attention::ExactKernel;
use conv_basis::coordinator::{
    AdmissionConfig, GenConfig, GenRequest, GenStatus, Server, ServerConfig,
};
use conv_basis::model::{AttentionBackend, ModelConfig, Transformer};
use conv_basis::tensor::Rng;
use std::sync::Arc;

fn tiny_model(seed: u64) -> Arc<Transformer> {
    let mut rng = Rng::seeded(seed);
    Arc::new(Transformer::new(&ModelConfig::tiny(64), &mut rng))
}

fn spec_server(
    model: Arc<Transformer>,
    backend: AttentionBackend,
    workers: usize,
    speculate: usize,
) -> Server {
    Server::start(ServerConfig {
        workers,
        cache_capacity: 256,
        gen: Some(GenConfig {
            model,
            backend,
            max_concurrent: 4,
            admission: AdmissionConfig::default(),
            speculate,
        }),
        ..Default::default()
    })
}

/// The greedy oracle: one full exact re-prefill per token. Everything
/// the speculative scheduler emits must match this bit for bit.
fn oracle(model: &Transformer, prompt: &[usize], max_new: usize) -> Vec<usize> {
    let mut toks = prompt.to_vec();
    let mut out = Vec::new();
    for _ in 0..max_new {
        let rec = model.forward(&toks, &AttentionBackend::Exact(ExactKernel::RowStream), false);
        let row = rec.logits.row(toks.len() - 1);
        let mut best = 0;
        for (i, &x) in row.iter().enumerate() {
            if x > row[best] {
                best = i;
            }
        }
        out.push(best);
        if toks.len() == model.cfg.max_seq {
            break;
        }
        toks.push(best);
    }
    out
}

/// Mixed-length prompts exercising different session sizes per wave.
fn prompts() -> Vec<Vec<usize>> {
    vec![
        vec![1, 2, 3, 4],
        vec![9, 8, 7],
        vec![5; 10],
        vec![2, 4, 6, 8, 10, 12, 1, 3, 5],
    ]
}

fn run_server(server: &Server, prompts: &[Vec<usize>], max_new: usize) -> Vec<Vec<usize>> {
    for (i, p) in prompts.iter().enumerate() {
        server.submit_generate(GenRequest::new(i as u64, p.clone(), max_new));
    }
    let mut resps = server.collect_generations(prompts.len());
    resps.sort_by_key(|r| r.id);
    assert!(resps.iter().all(|r| r.status == GenStatus::Complete));
    resps.into_iter().map(|r| r.tokens).collect()
}

#[test]
fn speculative_greedy_bit_matches_oracle_for_all_gammas_and_worker_counts() {
    let model = tiny_model(71);
    // max_new = 9 keeps every flight clear of the γ_eff = 0 tail on
    // full acceptance (remaining − 1 never hits 0 mid-flight for these
    // γ), so the token accounting below is exact, not just bounded:
    // every token is either the prefill emission (one per request), an
    // accepted draft, or a round's bonus.
    let max_new = 9;
    let want: Vec<Vec<usize>> = prompts().iter().map(|p| oracle(&model, p, max_new)).collect();
    for gamma in [1usize, 2, 4, 8] {
        for workers in [1usize, 2, 8] {
            let exact = AttentionBackend::Exact(ExactKernel::RowStream);
            let server = spec_server(model.clone(), exact, workers, gamma);
            let got = run_server(&server, &prompts(), max_new);
            let s = server.shutdown().snapshot();
            assert_eq!(
                got, want,
                "speculative (γ={gamma}, workers={workers}) diverged from greedy oracle"
            );
            let n_req = prompts().len() as u64;
            assert!(s.spec_rounds >= 1, "γ={gamma} must speculate");
            // Exact drafts bit-match the exact verifier: full acceptance.
            assert_eq!(s.spec_accepted, s.spec_drafted, "exact drafts must all verify");
            // ISSUE counter pin (exact form): accepted ≥ tokens −
            // prefill emissions − rounds; here it holds with equality.
            assert!(s.spec_accepted >= s.gen_tokens - n_req - s.spec_rounds);
            assert_eq!(s.gen_tokens, n_req + s.spec_accepted + s.spec_rounds);
            // Speculation must amortise: strictly fewer decode-lane
            // sub-steps than tokens generated (the whole point).
            let per_step = (model.cfg.n_layers * model.cfg.n_heads) as u64;
            assert_eq!(s.decode_steps % per_step, 0);
            assert!(
                s.decode_steps / per_step < s.gen_tokens,
                "γ={gamma}: {} decode sub-steps for {} tokens",
                s.decode_steps / per_step,
                s.gen_tokens
            );
            if gamma >= 2 {
                // Multi-token rounds: far fewer rounds than tokens.
                assert!(s.spec_rounds < s.gen_tokens - n_req);
            }
        }
    }
}

#[test]
fn broken_conv_drafter_still_emits_the_exact_oracle_stream() {
    // Adversarial arm: ConvStrided(1) is a deliberately crude drafter —
    // a single conv basis approximating whole attention rows. Its
    // drafts drift from the exact argmax, so the verifier rejects; the
    // emitted stream must STILL be the exact-greedy oracle's, bit for
    // bit (speculation *upgrades* a conv server to exact greedy:
    // exactness rests on the verifier, not the drafter), and every
    // round must make progress (the bonus token — no livelock).
    let model = tiny_model(72);
    let max_new = 12;
    let long_prompts: Vec<Vec<usize>> = vec![
        (1..=20).collect(),
        (0..16).map(|i| (i * 7) % 13 + 1).collect(),
        vec![3; 24],
    ];
    let want: Vec<Vec<usize>> =
        long_prompts.iter().map(|p| oracle(&model, p, max_new)).collect();
    let server = spec_server(model.clone(), AttentionBackend::ConvStrided(1), 2, 4);
    let got = run_server(&server, &long_prompts, max_new);
    let s = server.shutdown().snapshot();
    assert_eq!(got, want, "conv-drafted speculation must emit the exact oracle stream");
    assert!(s.spec_rounds >= 1);
    // Every rejected draft is counted (and none leaked into the
    // stream — the bit-identity above is the leak detector).
    assert!(
        s.spec_accepted < s.spec_drafted,
        "a k=1 conv drafter matching exact argmax on all {} drafts is a bug magnet — \
         accepted {} of {}",
        s.spec_drafted,
        s.spec_accepted,
        s.spec_drafted
    );
    // No livelock: every speculative round emitted at least its bonus.
    let n_req = long_prompts.len() as u64;
    assert!(
        s.gen_tokens - n_req >= s.spec_rounds,
        "rounds ({}) outnumber decoded tokens ({})",
        s.spec_rounds,
        s.gen_tokens - n_req
    );
}

#[test]
fn gamma_zero_is_the_identity_scheduler_path() {
    // γ = 0 must run the plain one-token-per-step loop — same tokens,
    // same decode-step count, and not a single speculative counter.
    let model = tiny_model(73);
    let max_new = 6;
    let want: Vec<Vec<usize>> = prompts().iter().map(|p| oracle(&model, p, max_new)).collect();
    let server = spec_server(model.clone(), AttentionBackend::Exact(ExactKernel::RowStream), 2, 0);
    let got = run_server(&server, &prompts(), max_new);
    let s = server.shutdown().snapshot();
    assert_eq!(got, want);
    assert_eq!(s.spec_rounds, 0, "γ = 0 must never speculate");
    assert_eq!(s.spec_drafted, 0);
    assert_eq!(s.spec_accepted, 0);
    // Exactly one decode sub-step per non-prefill token — the plain
    // path's signature (speculation would change this count).
    let per_step = (model.cfg.n_layers * model.cfg.n_heads) as u64;
    let n_req = prompts().len() as u64;
    assert_eq!(s.decode_steps, (max_new as u64 - 1) * n_req * per_step);
    assert_eq!(s.gen_tokens, n_req * max_new as u64);
}

#[test]
fn per_request_speculate_knob_overrides_the_server_default() {
    let model = tiny_model(74);
    let max_new = 9;
    let p = vec![1, 2, 3, 4, 5];
    let want = oracle(&model, &p, max_new);

    // Opt IN on a γ = 0 server.
    let server = spec_server(model.clone(), AttentionBackend::Exact(ExactKernel::RowStream), 2, 0);
    server.submit_generate(GenRequest::new(0, p.clone(), max_new).with_speculate(4));
    let resp = server.collect_generations(1);
    let s = server.shutdown().snapshot();
    assert_eq!(resp[0].tokens, want);
    assert!(s.spec_rounds >= 1, "per-request speculate must engage on a γ=0 server");

    // Opt OUT on a γ = 4 server.
    let server = spec_server(model.clone(), AttentionBackend::Exact(ExactKernel::RowStream), 2, 4);
    server.submit_generate(GenRequest::new(0, p.clone(), max_new).with_speculate(0));
    let resp = server.collect_generations(1);
    let s = server.shutdown().snapshot();
    assert_eq!(resp[0].tokens, want);
    assert_eq!(s.spec_rounds, 0, "speculate: 0 must opt a request out entirely");
}

#[test]
fn mixed_gammas_in_one_wave_all_match_the_oracle() {
    // Flights with different γ share scheduler rounds: the γ-sorted
    // prefix sub-steps and the γ_eff = 0 flights riding sub-step 0
    // must not perturb each other — every stream stays the oracle's.
    let model = tiny_model(75);
    let max_new = 9;
    let ps = prompts();
    let gammas = [0usize, 1, 8, 3];
    let want: Vec<Vec<usize>> = ps.iter().map(|p| oracle(&model, p, max_new)).collect();
    for workers in [1usize, 2, 8] {
        let exact = AttentionBackend::Exact(ExactKernel::RowStream);
        let server = spec_server(model.clone(), exact, workers, 2);
        for (i, p) in ps.iter().enumerate() {
            server.submit_generate(
                GenRequest::new(i as u64, p.clone(), max_new).with_speculate(gammas[i]),
            );
        }
        let mut resps = server.collect_generations(ps.len());
        resps.sort_by_key(|r| r.id);
        let s = server.shutdown().snapshot();
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(
                r.tokens, want[i],
                "mixed-γ wave (workers={workers}): request {i} (γ={}) diverged",
                gammas[i]
            );
        }
        assert!(s.spec_rounds >= 1);
        assert_eq!(s.spec_accepted, s.spec_drafted);
    }
}
