//! Backend-equivalence oracle harness for the adaptive approximation
//! router (PR 9 headline): every route the `RouterPolicy` can pick
//! must agree with the dense oracle within a *documented* tolerance,
//! and routing decisions must be bit-reproducible across worker
//! counts, runs, and lane mixes.
//!
//! # The documented low-rank tolerance (`LOWRANK_RTOL`)
//!
//! The low-rank route is the only approximate one (exact is exact;
//! conv falls back to exact whenever recovery fails), so its error
//! budget is the router's whole approximation story. Theorem 6.5
//! bounds the normalized attention error by `4ε‖V‖∞` where `ε` is
//! the relative error of the truncated-Taylor exponential features.
//! For the harness inputs — entries uniform in `[-0.4, 0.4)`, head
//! dim `d = 4`, AS23 scale `β = d = 4` — the logits satisfy
//! `|x| = |q·k|/β ≤ 4·0.4²/4 = 0.16`, and the degree-`g` Lagrange
//! remainder gives
//!
//! * `g = 1`: `ε ≤ |x|²/2 · e^|x| ≈ 1.6e-2` → normalized error
//!   `≲ 3.6e-2 · ‖V‖∞`; we pin **`0.08 · ‖V‖∞`** (≈2× margin);
//! * `g = 2`: `ε ≤ |x|³/6 · e^|x| ≈ 8e-4` → normalized error
//!   `≲ 2e-3 · ‖V‖∞`; we pin **`0.01 · ‖V‖∞`** (≈5× margin).
//!
//! These are analytic, worst-case bounds — no measured slack — so the
//! assertions hold for every `n` (the bound is per-row and
//! `n`-independent) and every seed.

use std::sync::Arc;

use conv_basis::attention::batched::{
    AttnJob, BatchedBackend, BatchedEngine, EngineConfig, EngineJob, HeadRoute,
    ProfilePolicyConfig, RouterPolicy,
};
use conv_basis::attention::rope::rope_structured_qk;
use conv_basis::attention::ExactKernel;
use conv_basis::attention::Mask;
use conv_basis::basis::RecoverConfig;
use conv_basis::coordinator::{Metrics, RouteKind};
use conv_basis::lowrank::{exact_scaled_attention, LowRankConfig};
use conv_basis::model::{AttentionBackend, ModelConfig, Transformer};
use conv_basis::tensor::{linf_norm_mat, max_abs_diff, Matrix, Rng};

/// Documented low-rank tolerance: normalized attention error bound
/// per Taylor degree, as a multiple of `‖V‖∞` (derivation above).
fn lowrank_rtol(degree: usize) -> f64 {
    match degree {
        1 => 0.08,
        2 => 0.01,
        other => panic!("no documented tolerance for degree {other}"),
    }
}

/// Harness inputs for the low-rank oracle comparison: entries bounded
/// so the documented Taylor remainder applies.
fn bounded_inputs(n: usize, d: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::seeded(seed);
    let q = Matrix::rand_uniform(n, d, 0.4, &mut rng);
    let k = Matrix::rand_uniform(n, d, 0.4, &mut rng);
    let v = Matrix::rand_uniform(n, d, 0.4, &mut rng);
    (q, k, v)
}

fn prefill(e: &BatchedEngine, jobs: Vec<AttnJob>) -> Vec<Matrix> {
    e.submit(jobs.into_iter().enumerate().map(|(i, j)| EngineJob::prefill(i as u64, j)).collect())
        .into_iter()
        .map(|o| o.result.into_prefill().y)
        .collect()
}

/// Satellite (a): the low-rank causal route matches the dense scaled
/// oracle within the documented tolerance at every harness size and
/// degree.
#[test]
fn lowrank_route_matches_dense_oracle_within_documented_rtol() {
    let d = 4;
    let scale = d as f64; // the AS23 β = d convention
    for n in [8usize, 32, 64] {
        for degree in [1usize, 2] {
            let (q, k, v) = bounded_inputs(n, d, 0x900 + (n as u64) * 10 + degree as u64);
            let oracle = exact_scaled_attention(&q, &k, &v, &Mask::causal(n), scale);
            let cfg = LowRankConfig::new(degree, scale);
            let e = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 8 });
            let ys = prefill(
                &e,
                vec![AttnJob::causal(0, 0, q, k, v.clone(), BatchedBackend::LowRank(cfg))],
            );
            let err = max_abs_diff(&ys[0], &oracle);
            let tol = lowrank_rtol(degree) * linf_norm_mat(&v);
            assert!(
                err <= tol,
                "n={n} degree={degree}: low-rank error {err:.3e} exceeds \
                 documented tolerance {tol:.3e}"
            );
        }
    }
}

/// The mixed static table the equivalence tests route through: all
/// four operator families across 2 layers × 3 heads, plus one head
/// left to the policy default.
fn mixed_table(n: usize) -> RouterPolicy {
    RouterPolicy::new(HeadRoute::Exact)
        .set(0, 0, HeadRoute::Exact)
        .set(0, 1, HeadRoute::Strided(4))
        .set(0, 2, HeadRoute::Conv(RecoverConfig::exact(n)))
        .set(1, 0, HeadRoute::LowRank(LowRankConfig::new(1, 4.0)))
        .set(1, 1, HeadRoute::Strided(2))
    // (1, 2) unset → policy default (Exact).
}

/// The direct backend each slot of [`mixed_table`] must resolve to.
fn direct_backends(n: usize) -> Vec<((u32, u32), BatchedBackend)> {
    vec![
        ((0, 0), BatchedBackend::Exact(ExactKernel::RowStream)),
        ((0, 1), BatchedBackend::Strided(4)),
        ((0, 2), BatchedBackend::Conv(RecoverConfig::exact(n))),
        ((1, 0), BatchedBackend::LowRank(LowRankConfig::new(1, 4.0))),
        ((1, 1), BatchedBackend::Strided(2)),
        ((1, 2), BatchedBackend::Exact(ExactKernel::RowStream)),
    ]
}

/// Per-(layer, head) inputs: rope-structured Q/K (conv-recoverable)
/// except the low-rank head, which gets the bounded harness inputs.
fn mixed_inputs(n: usize) -> Vec<((u32, u32), (Matrix, Matrix, Matrix))> {
    direct_backends(n)
        .iter()
        .map(|((layer, head), backend)| {
            let seed = 0xB0 + (*layer as u64) * 8 + *head as u64;
            let qkv = if matches!(backend, BatchedBackend::LowRank(_)) {
                bounded_inputs(n, 4, seed)
            } else {
                let mut rng = Rng::seeded(seed);
                let (q, k) = rope_structured_qk(n, 4, 2, &mut rng);
                (q, k, Matrix::randn(n, 4, &mut rng))
            };
            ((*layer, *head), qkv)
        })
        .collect()
}

/// Satellite (b): a mixed static routing table is bit-identical
/// across worker counts 1/2/8 AND bit-identical to running each
/// head's resolved backend directly — the routed path adds zero
/// float ops.
#[test]
fn mixed_table_bit_identical_across_workers_and_vs_direct_backends() {
    let n = 48;
    let policy = Arc::new(mixed_table(n));
    let inputs = mixed_inputs(n);

    // Each head's backend run individually (fresh engine per head so
    // no cache interplay) — the bitwise oracle for every routed slot.
    let direct: Vec<Matrix> = direct_backends(n)
        .into_iter()
        .zip(&inputs)
        .map(|((slot, backend), (islot, (q, k, v)))| {
            assert_eq!(slot, *islot);
            let e = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 8 });
            prefill(
                &e,
                vec![AttnJob::causal(slot.0, slot.1, q.clone(), k.clone(), v.clone(), backend)],
            )
            .remove(0)
        })
        .collect();

    let mut per_worker: Vec<Vec<Matrix>> = Vec::new();
    for workers in [1usize, 2, 8] {
        let e = BatchedEngine::new(EngineConfig { workers, cache_capacity: 16 });
        let jobs: Vec<AttnJob> = inputs
            .iter()
            .map(|((layer, head), (q, k, v))| {
                AttnJob::causal(
                    *layer,
                    *head,
                    q.clone(),
                    k.clone(),
                    v.clone(),
                    BatchedBackend::Routed(Arc::clone(&policy)),
                )
            })
            .collect();
        let ys = prefill(&e, jobs);

        // Routing decisions counter-asserted per worker count: the
        // same table must tally the same routes regardless of fan-out.
        let snap = e.metrics().snapshot();
        assert_eq!(snap.routed_jobs, 6, "{workers} workers");
        assert_eq!(
            (snap.router_exact_routes, snap.router_conv_routes, snap.router_lowrank_routes),
            (2, 3, 1),
            "{workers} workers: route tally"
        );
        assert_eq!(snap.router_rank_refusals, 0, "{workers} workers");

        for (y, oracle) in ys.iter().zip(&direct) {
            assert_eq!(
                max_abs_diff(y, oracle),
                0.0,
                "{workers} workers: routed output differs from its direct backend"
            );
        }
        per_worker.push(ys);
    }
    for ys in &per_worker[1..] {
        for (a, b) in ys.iter().zip(&per_worker[0]) {
            assert_eq!(max_abs_diff(a, b), 0.0, "bit drift across worker counts");
        }
    }
}

/// Feed a `Metrics` the measured history that must drive all three
/// `from_profile` decision rows (identical every call — the point).
fn feed_profile(m: &Metrics) {
    use std::time::Duration;
    let exec = Duration::from_micros(50);
    // (0, 0): 3/4 jobs fell back → fallback_rate 0.75 > 0.5 → Exact.
    for i in 0..4 {
        m.record_head_job(0, 0, RouteKind::Conv, i < 3, exec);
    }
    // (0, 1): clean conv, tiny recovery error → stays on conv.
    for _ in 0..4 {
        m.record_head_job(0, 1, RouteKind::Conv, false, exec);
        m.record_head_recovery_err(0, 1, 1e-5);
    }
    // (0, 2): clean conv but large recovery error → low-rank.
    for _ in 0..4 {
        m.record_head_job(0, 2, RouteKind::Conv, false, exec);
        m.record_head_recovery_err(0, 2, 1e-2);
    }
}

/// Satellite (c): a profile-driven policy with pinned thresholds makes
/// the same decisions on two identical runs — asserted structurally
/// (the policies compare equal) and operationally (two identical
/// routed runs render identical `router_report` lines and outputs).
#[test]
fn profile_driven_policy_is_run_to_run_deterministic() {
    let cfg = ProfilePolicyConfig {
        max_fallback_rate: 0.5,
        max_recovery_err: 1e-3,
        conv: HeadRoute::Strided(4),
        lowrank: LowRankConfig::new(2, 4.0),
    };

    // Two independently-fed metrics sinks → identical policies.
    let policies: Vec<RouterPolicy> = (0..2)
        .map(|_| {
            let m = Metrics::new();
            feed_profile(&m);
            RouterPolicy::from_profile(&m.head_profiles(), &cfg)
        })
        .collect();
    assert_eq!(policies[0], policies[1], "profile-driven decisions drifted between runs");
    assert_eq!(*policies[0].route(0, 0), HeadRoute::Exact);
    assert_eq!(*policies[0].route(0, 1), HeadRoute::Strided(4));
    assert_eq!(*policies[0].route(0, 2), HeadRoute::LowRank(LowRankConfig::new(2, 4.0)));
    // Unprofiled heads take the pinned conv default.
    assert_eq!(*policies[0].route(7, 7), HeadRoute::Strided(4));

    // Two identical routed runs → identical router_report lines.
    let n = 32;
    let policy = Arc::new(policies[0].clone());
    let reports: Vec<(String, Vec<Matrix>)> = (0..2)
        .map(|_| {
            let e = BatchedEngine::new(EngineConfig { workers: 4, cache_capacity: 8 });
            let jobs: Vec<AttnJob> = (0..3)
                .map(|head| {
                    let (q, k, v) = bounded_inputs(n, 4, 0xC0 + head as u64);
                    AttnJob::causal(0, head, q, k, v, BatchedBackend::Routed(Arc::clone(&policy)))
                })
                .collect();
            let ys = prefill(&e, jobs);
            (e.metrics().snapshot().router_report(), ys)
        })
        .collect();
    assert_eq!(reports[0].0, reports[1].0, "router_report drifted between identical runs");
    assert_eq!(
        reports[0].0,
        "router: 3 routed jobs | routes: exact=1 conv=1 lowrank=1 | \
         rank refusals: 0 | decode pins: 0"
    );
    for (a, b) in reports[0].1.iter().zip(&reports[1].1) {
        assert_eq!(max_abs_diff(a, b), 0.0, "routed outputs drifted between identical runs");
    }
}

/// Satellite (d): low-rank routes cannot seed decode state — a
/// decode-bound session routed through a table with low-rank slots is
/// pinned to the exact decode kernel, the pin is counted, and no
/// basis seeding is attempted. An all-exact table must then decode
/// bit-identically to the direct exact backend.
#[test]
fn lowrank_routed_sessions_are_pinned_to_exact_decode_and_counted() {
    let cfg = ModelConfig {
        vocab_size: 16,
        d_model: 8,
        n_heads: 2,
        n_layers: 2,
        d_ff: 16,
        max_seq: 32,
    };
    let mut rng = Rng::seeded(0xD1);
    let model = Transformer::new(&cfg, &mut rng);
    let prompts: Vec<Vec<usize>> = vec![vec![1, 2, 3, 4, 5, 6, 7, 8], vec![9, 10, 11, 12, 13, 14]];

    // head_dim = 4, degree 1 → rank C(5,1) = 5 < both prompt lengths,
    // so the low-rank slot is viable for *prefill* — the decode pin we
    // assert below is purely table-driven, not a viability fallback.
    let lowrank_policy = Arc::new(
        RouterPolicy::new(HeadRoute::Exact).set(0, 0, HeadRoute::LowRank(LowRankConfig::new(
            1, 1.0,
        ))),
    );
    let routed = AttentionBackend::Routed(Arc::clone(&lowrank_policy));
    let e = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 16 });
    let mut sessions = model.prefill_batch(&prompts, &routed, &e);
    let snap = e.metrics().snapshot();
    // One low-rank table slot × two sessions, each pinned to exact.
    assert_eq!(snap.router_decode_pins, 2, "every low-rank slot pins per decode session");
    // Pinned-to-exact sessions never touch the basis-seeding path.
    assert_eq!(
        (snap.decode_seed_hits, snap.decode_seed_misses),
        (0, 0),
        "a routed decode-bound session must not attempt basis seeding"
    );

    // The pinned sessions decode: one greedy step produces finite
    // logits through the exact decode kernel.
    let (mut s, _logits): (Vec<_>, Vec<_>) = sessions.drain(..).unzip();
    let step = model.decode_step(&mut s, &[3, 5], &e);
    assert_eq!(step.len(), 2);
    assert!(step.iter().all(|l| l.iter().all(|x| x.is_finite())));

    // Oracle pin: an all-exact routed table is bit-identical to the
    // direct exact backend through prefill AND decode.
    let exact_policy = Arc::new(RouterPolicy::new(HeadRoute::Exact));
    let routed_exact = AttentionBackend::Routed(exact_policy);
    let er = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 16 });
    let eo = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 16 });
    let mut via_router = model.prefill_batch(&prompts, &routed_exact, &er);
    let exact = AttentionBackend::Exact(ExactKernel::RowStream);
    let mut via_exact = model.prefill_batch(&prompts, &exact, &eo);
    for ((_, lr), (_, le)) in via_router.iter().zip(&via_exact) {
        assert_eq!(lr, le, "routed-exact prefill logits must bit-match direct exact");
    }
    let (mut sr, _): (Vec<_>, Vec<_>) = via_router.drain(..).unzip();
    let (mut se, _): (Vec<_>, Vec<_>) = via_exact.drain(..).unzip();
    let dr = model.decode_step(&mut sr, &[3, 5], &er);
    let de = model.decode_step(&mut se, &[3, 5], &eo);
    assert_eq!(dr, de, "routed-exact decode logits must bit-match direct exact");
}
