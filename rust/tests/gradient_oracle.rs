//! Dense-oracle harness for the engine-routed LM backward (ISSUE 4).
//!
//! Three pins, in increasing strength:
//!
//! 1. **Bit-identity** — `Transformer::backward_with_engine` in exact
//!    mode reproduces the dense `Transformer::backward` **bit for
//!    bit**, on every parameter group, for worker counts 1/2/8 and for
//!    micro-batched backwards (the Linformer-style oracle-comparison
//!    methodology, taken to equality instead of tolerance).
//! 2. **Analytic correctness** — a central finite-difference check
//!    bounds the engine-routed gradient's error on every parameter
//!    group (embed, wq/wk/wv/wo, ln1/ln2, w1/w2, lnf, head, cls_head).
//! 3. **Fast-path accuracy** — the conv-basis backward stays within a
//!    documented tolerance of exact on a trained model, the `train_lm`
//!    fast loss curve tracks the exact curve, and recovery failure is
//!    *reported* (`grad_fallbacks`) rather than silently diverging.

use conv_basis::attention::batched::{BatchedEngine, EngineConfig};
use conv_basis::attention::ExactKernel;
use conv_basis::gradient::batched::{AttnBackwardMode, FastGradConfig};
use conv_basis::model::{
    train_lm_with_engine, AttentionBackend, Gradients, ModelConfig, TrainAttentionMode,
    TrainConfig, Transformer,
};
use conv_basis::tensor::{max_abs_diff, Matrix, Rng};

fn oracle_model(seed: u64, max_seq: usize) -> Transformer {
    // The ISSUE-specified harness model: 2 layers × 2 heads.
    let cfg = ModelConfig {
        vocab_size: 16,
        d_model: 8,
        n_heads: 2,
        n_layers: 2,
        d_ff: 16,
        max_seq,
    };
    let mut rng = Rng::seeded(seed);
    Transformer::new(&cfg, &mut rng)
}

fn random_tokens(n: usize, vocab: usize, rng: &mut Rng) -> Vec<usize> {
    (0..n).map(|_| rng.below(vocab)).collect()
}

/// Bitwise equality over the full parameter-group structure.
fn assert_grads_bit_identical(a: &Gradients, b: &Gradients, ctx: &str) {
    assert_eq!(max_abs_diff(&a.embed, &b.embed), 0.0, "{ctx}: embed");
    assert_eq!(max_abs_diff(&a.head, &b.head), 0.0, "{ctx}: head");
    assert_eq!(max_abs_diff(&a.cls_head, &b.cls_head), 0.0, "{ctx}: cls_head");
    assert_eq!(a.lnf_g, b.lnf_g, "{ctx}: lnf_g");
    for (li, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        assert_eq!(la.ln1_g, lb.ln1_g, "{ctx}: layer {li} ln1_g");
        assert_eq!(la.ln2_g, lb.ln2_g, "{ctx}: layer {li} ln2_g");
        for (ma, mb, name) in [
            (&la.wq, &lb.wq, "wq"),
            (&la.wk, &lb.wk, "wk"),
            (&la.wv, &lb.wv, "wv"),
            (&la.wo, &lb.wo, "wo"),
            (&la.w1, &lb.w1, "w1"),
            (&la.w2, &lb.w2, "w2"),
        ] {
            assert_eq!(max_abs_diff(ma, mb), 0.0, "{ctx}: layer {li} {name}");
        }
    }
}

#[test]
fn engine_exact_backward_bitmatches_dense_oracle() {
    // The acceptance pin: engine-routed exact LM backward ≡ dense
    // backward, bit for bit, on a 2-layer 2-head model at n ∈ {8, 32},
    // across worker counts 1/2/8.
    let m = oracle_model(4001, 32);
    for n in [8usize, 32] {
        let mut rng = Rng::seeded(4002 + n as u64);
        let tokens = random_tokens(n, 16, &mut rng);
        let targets = random_tokens(n, 16, &mut rng);
        let rec = m.forward(&tokens, &AttentionBackend::Exact(ExactKernel::RowStream), true);
        let (_, dlogits) = m.lm_loss(&rec, &targets, usize::MAX);

        let mut dense = m.zero_grads();
        m.backward(&rec, &dlogits, None, &mut dense);

        for workers in [1usize, 2, 8] {
            let engine = BatchedEngine::new(EngineConfig { workers, cache_capacity: 32 });
            let mut routed = m.zero_grads();
            m.backward_with_engine(
                &rec,
                &dlogits,
                None,
                &mut routed,
                &engine,
                &AttnBackwardMode::Exact(ExactKernel::RowStream),
            );
            assert_grads_bit_identical(&dense, &routed, &format!("n={n} workers={workers}"));
            let snap = engine.metrics().snapshot();
            assert_eq!(snap.lm_backward_calls, 2, "one submit per layer");
            assert_eq!(snap.lm_backward_jobs, 4, "2 layers × 2 heads");
        }
    }
}

#[test]
fn engine_batched_backward_bitmatches_sequential_dense() {
    // Micro-batched backward (what train_lm issues): one
    // backward_batch_with_engine call over three records must equal
    // three sequential dense backwards accumulated in the same grads.
    let m = oracle_model(4005, 32);
    let mut rng = Rng::seeded(4006);
    let seqs: Vec<(Vec<usize>, Vec<usize>)> = [8usize, 12, 32]
        .iter()
        .map(|&n| (random_tokens(n, 16, &mut rng), random_tokens(n, 16, &mut rng)))
        .collect();
    let exact = AttentionBackend::Exact(ExactKernel::RowStream);
    let recs: Vec<_> = seqs.iter().map(|(t, _)| m.forward(t, &exact, true)).collect();
    let dls: Vec<Matrix> = recs
        .iter()
        .zip(&seqs)
        .map(|(r, (_, y))| m.lm_loss(r, y, usize::MAX).1)
        .collect();

    let mut dense = m.zero_grads();
    for (r, dl) in recs.iter().zip(&dls) {
        m.backward(r, dl, None, &mut dense);
    }

    for workers in [1usize, 2, 8] {
        let engine = BatchedEngine::new(EngineConfig { workers, cache_capacity: 32 });
        let mut routed = m.zero_grads();
        let batch: Vec<_> = recs.iter().zip(&dls).map(|(r, dl)| (r, dl, None)).collect();
        let mode = AttnBackwardMode::Exact(ExactKernel::RowStream);
        m.backward_batch_with_engine(&batch, &mut routed, &engine, &mode);
        assert_grads_bit_identical(&dense, &routed, &format!("batched workers={workers}"));
    }
}

#[test]
fn engine_backward_matches_finite_differences_every_parameter_group() {
    // Central finite differences bound the analytic (engine-routed)
    // gradient on EVERY parameter group.
    let m = oracle_model(4010, 16);
    let tokens = [3usize, 1, 4, 1, 5, 9, 2, 6];
    let targets = [1usize, 4, 1, 5, 9, 2, 6, 5];
    let rec = m.forward(&tokens, &AttentionBackend::Exact(ExactKernel::RowStream), true);
    let (_, dlogits) = m.lm_loss(&rec, &targets, usize::MAX);
    let engine = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 32 });
    let mut grads = m.zero_grads();
    let mode = AttnBackwardMode::Exact(ExactKernel::RowStream);
    m.backward_with_engine(&rec, &dlogits, None, &mut grads, &engine, &mode);

    let eps = 1e-5;
    let loss_with = |m: &Transformer| {
        let r = m.forward(&tokens, &AttentionBackend::Exact(ExactKernel::RowStream), false);
        m.lm_loss(&r, &targets, usize::MAX).0
    };
    let check = |fd: f64, an: f64, name: &str| {
        assert!((fd - an).abs() < 1e-4 * (1.0 + an.abs()), "{name}: fd={fd} an={an}");
    };

    // Per-layer matrix groups, one spot entry each, both layers.
    for li in 0..2 {
        for (name, pick) in [
            ("wq", 0usize),
            ("wk", 1),
            ("wv", 2),
            ("wo", 3),
            ("w1", 4),
            ("w2", 5),
        ] {
            let (i, j) = (2 + li, 3);
            let (mut mp, mut mm) = (m.clone(), m.clone());
            {
                let (lp, lm) = (&mut mp.layers[li], &mut mm.layers[li]);
                let (tp, tm): (&mut Matrix, &mut Matrix) = match pick {
                    0 => (&mut lp.wq, &mut lm.wq),
                    1 => (&mut lp.wk, &mut lm.wk),
                    2 => (&mut lp.wv, &mut lm.wv),
                    3 => (&mut lp.wo, &mut lm.wo),
                    4 => (&mut lp.w1, &mut lm.w1),
                    _ => (&mut lp.w2, &mut lm.w2),
                };
                tp[(i, j)] += eps;
                tm[(i, j)] -= eps;
            }
            let fd = (loss_with(&mp) - loss_with(&mm)) / (2.0 * eps);
            let gl = &grads.layers[li];
            let an = match pick {
                0 => gl.wq[(i, j)],
                1 => gl.wk[(i, j)],
                2 => gl.wv[(i, j)],
                3 => gl.wo[(i, j)],
                4 => gl.w1[(i, j)],
                _ => gl.w2[(i, j)],
            };
            check(fd, an, &format!("layer {li} {name}"));
        }
        // Norm gains.
        for (name, is_ln1) in [("ln1_g", true), ("ln2_g", false)] {
            let j = 4 + li;
            let (mut mp, mut mm) = (m.clone(), m.clone());
            if is_ln1 {
                mp.layers[li].ln1_g[j] += eps;
                mm.layers[li].ln1_g[j] -= eps;
            } else {
                mp.layers[li].ln2_g[j] += eps;
                mm.layers[li].ln2_g[j] -= eps;
            }
            let fd = (loss_with(&mp) - loss_with(&mm)) / (2.0 * eps);
            let an = if is_ln1 { grads.layers[li].ln1_g[j] } else { grads.layers[li].ln2_g[j] };
            check(fd, an, &format!("layer {li} {name}"));
        }
    }
    // Embedding (token 1 appears twice), final norm, LM head.
    for &j in &[0usize, 7] {
        let (mut mp, mut mm) = (m.clone(), m.clone());
        mp.embed[(1, j)] += eps;
        mm.embed[(1, j)] -= eps;
        let fd = (loss_with(&mp) - loss_with(&mm)) / (2.0 * eps);
        check(fd, grads.embed[(1, j)], &format!("embed(1,{j})"));
    }
    let (mut mp, mut mm) = (m.clone(), m.clone());
    mp.lnf_g[2] += eps;
    mm.lnf_g[2] -= eps;
    check((loss_with(&mp) - loss_with(&mm)) / (2.0 * eps), grads.lnf_g[2], "lnf_g");
    let (mut mp, mut mm) = (m.clone(), m.clone());
    mp.head[(5, 9)] += eps;
    mm.head[(5, 9)] -= eps;
    check((loss_with(&mp) - loss_with(&mm)) / (2.0 * eps), grads.head[(5, 9)], "head");

    // cls_head rides the classification gradient path.
    let (_, _, dcls) = m.cls_loss(&rec, true);
    let mut cgrads = m.zero_grads();
    let zero = Matrix::zeros(tokens.len(), 16);
    let mode = AttnBackwardMode::Exact(ExactKernel::RowStream);
    m.backward_with_engine(&rec, &zero, Some(dcls), &mut cgrads, &engine, &mode);
    let cls_loss_with = |m: &Transformer| {
        let r = m.forward(&tokens, &AttentionBackend::Exact(ExactKernel::RowStream), false);
        m.cls_loss(&r, true).0
    };
    let (mut mp, mut mm) = (m.clone(), m.clone());
    mp.cls_head[(3, 1)] += eps;
    mm.cls_head[(3, 1)] -= eps;
    let fd = (cls_loss_with(&mp) - cls_loss_with(&mm)) / (2.0 * eps);
    check(fd, cgrads.cls_head[(3, 1)], "cls_head");
}

/// Documented fast-path tolerance: with exact-recovery configuration
/// the conv `f`-operator equals the dense softmax to FFT rounding
/// (~1e-9 entrywise), and after flowing through the full multi-layer
/// chain the parameter gradients agree with the exact backward to
/// `1e-6` relative — the bound this test pins.
const FAST_BACKWARD_RTOL: f64 = 1e-6;

fn grads_close(a: &Gradients, b: &Gradients, rtol: f64, ctx: &str) {
    let pairs: Vec<(&Matrix, &Matrix, String)> = a
        .layers
        .iter()
        .zip(&b.layers)
        .enumerate()
        .flat_map(|(li, (la, lb))| {
            vec![
                (&la.wq, &lb.wq, format!("{ctx} layer {li} wq")),
                (&la.wk, &lb.wk, format!("{ctx} layer {li} wk")),
                (&la.wv, &lb.wv, format!("{ctx} layer {li} wv")),
                (&la.wo, &lb.wo, format!("{ctx} layer {li} wo")),
            ]
        })
        .chain(std::iter::once((&a.embed, &b.embed, format!("{ctx} embed"))))
        .chain(std::iter::once((&a.head, &b.head, format!("{ctx} head"))))
        .collect();
    for (ga, gb, name) in pairs {
        let scale = 1.0 + gb.data().iter().fold(0.0f64, |m, x| m.max(x.abs()));
        let err = max_abs_diff(ga, gb) / scale;
        assert!(err < rtol, "{name}: relative err {err} ≥ {rtol}");
    }
}

#[test]
fn fast_backward_within_documented_tolerance_on_trained_model() {
    // Train a few steps (exact), then compare the conv-basis backward
    // against the exact backward on a fresh batch.
    let mcfg = ModelConfig {
        vocab_size: 260,
        d_model: 16,
        n_heads: 2,
        n_layers: 2,
        d_ff: 32,
        max_seq: 16,
    };
    let tcfg = TrainConfig { steps: 8, lr: 3e-3, seq_len: 16, batch: 2, log_every: 4, seed: 11 };
    let engine = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 64 });
    let (m, _) = train_lm_with_engine(
        &mcfg,
        &tcfg,
        2000,
        &engine,
        &TrainAttentionMode::Exact,
        &AttnBackwardMode::Exact(ExactKernel::RowStream),
    );

    let mut rng = Rng::seeded(4020);
    let tokens = random_tokens(16, 260, &mut rng);
    let targets = random_tokens(16, 260, &mut rng);
    let rec = m.forward(&tokens, &AttentionBackend::Exact(ExactKernel::RowStream), true);
    let (_, dlogits) = m.lm_loss(&rec, &targets, usize::MAX);

    let mut exact = m.zero_grads();
    let mode = AttnBackwardMode::Exact(ExactKernel::RowStream);
    m.backward_with_engine(&rec, &dlogits, None, &mut exact, &engine, &mode);
    let mut fast = m.zero_grads();
    let fast_mode = AttnBackwardMode::Fast(FastGradConfig {
        recover: conv_basis::basis::RecoverConfig::exact(16),
        use_cache: false,
    });
    m.backward_with_engine(&rec, &dlogits, None, &mut fast, &engine, &fast_mode);
    assert_eq!(
        engine.metrics().snapshot().lm_backward_fallbacks,
        0,
        "exact-config recovery cannot fail"
    );
    grads_close(&fast, &exact, FAST_BACKWARD_RTOL, "fast-vs-exact");
}

#[test]
fn fast_train_lm_loss_curve_tracks_exact() {
    // The whole training loop on the conv-basis backward: its loss
    // curve must track the exact-backward curve (same seeds, same
    // data) — every logged point within 10% relative or 0.05 absolute,
    // and both curves must decrease.
    let mcfg = ModelConfig {
        vocab_size: 260,
        d_model: 16,
        n_heads: 2,
        n_layers: 1,
        d_ff: 32,
        max_seq: 16,
    };
    let tcfg = TrainConfig { steps: 24, lr: 3e-3, seq_len: 16, batch: 2, log_every: 6, seed: 5 };
    let e1 = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 64 });
    let (_, log_exact) = train_lm_with_engine(
        &mcfg,
        &tcfg,
        2000,
        &e1,
        &TrainAttentionMode::Exact,
        &AttnBackwardMode::Exact(ExactKernel::RowStream),
    );
    let e2 = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 64 });
    let fast_mode = AttnBackwardMode::Fast(FastGradConfig {
        recover: conv_basis::basis::RecoverConfig::exact(16),
        use_cache: false,
    });
    let (_, log_fast) =
        train_lm_with_engine(&mcfg, &tcfg, 2000, &e2, &TrainAttentionMode::Exact, &fast_mode);

    assert_eq!(log_exact.losses.len(), log_fast.losses.len());
    for ((se, le), (sf, lf)) in log_exact.losses.iter().zip(&log_fast.losses) {
        assert_eq!(se, sf);
        let tol = 0.05 + 0.10 * le.abs();
        assert!(
            (le - lf).abs() < tol,
            "fast curve diverged at step {se}: exact={le} fast={lf}"
        );
    }
    let (first, last) = (log_exact.losses.first().unwrap().1, log_exact.losses.last().unwrap().1);
    assert!(last < first, "exact curve decreases: {first} → {last}");
    let (first, last) = (log_fast.losses.first().unwrap().1, log_fast.losses.last().unwrap().1);
    assert!(last < first, "fast curve decreases: {first} → {last}");
    assert_eq!(e2.metrics().snapshot().lm_backward_fallbacks, 0);
}

#[test]
fn cached_handle_backward_bitmatches_self_recovery() {
    // Zero-copy cache handles: a backward served from a cached
    // `Arc<CachedBasis>` (the `FOperator::from_cached` path — no copy
    // of the O(k·n) basis floats) must be **bit-identical** to the
    // cache-less backward that recovers the same operator from scratch.
    // Three passes over one engine: cache-less reference, a cold
    // `use_cache: true` pass that populates the cache, then a warm pass
    // that must hit on every (layer, head) — all three bit-equal.
    let m = oracle_model(4040, 16);
    let mut rng = Rng::seeded(4041);
    let tokens = random_tokens(16, 16, &mut rng);
    let targets = random_tokens(16, 16, &mut rng);
    let rec = m.forward(&tokens, &AttentionBackend::Exact(ExactKernel::RowStream), true);
    let (_, dlogits) = m.lm_loss(&rec, &targets, usize::MAX);
    let engine = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 32 });

    let nocache = AttnBackwardMode::Fast(FastGradConfig {
        recover: conv_basis::basis::RecoverConfig::exact(16),
        use_cache: false,
    });
    let mut reference = m.zero_grads();
    m.backward_with_engine(&rec, &dlogits, None, &mut reference, &engine, &nocache);

    let cached = AttnBackwardMode::Fast(FastGradConfig::exact(16)); // use_cache: true
    let mut cold = m.zero_grads();
    m.backward_with_engine(&rec, &dlogits, None, &mut cold, &engine, &cached);
    let snap = engine.metrics().snapshot();
    assert_eq!(snap.lm_backward_cache_misses, 4, "cold pass recovers 2 layers × 2 heads");
    assert_eq!(snap.lm_backward_cache_hits, 0);

    let mut warm = m.zero_grads();
    m.backward_with_engine(&rec, &dlogits, None, &mut warm, &engine, &cached);
    let snap = engine.metrics().snapshot();
    assert_eq!(snap.lm_backward_cache_hits, 4, "warm pass reuses every cached handle");
    assert_eq!(snap.lm_backward_fallbacks, 0);

    assert_grads_bit_identical(&reference, &cold, "cold-vs-selfrecovery");
    assert_grads_bit_identical(&reference, &warm, "cached-handle-vs-selfrecovery");
}

#[test]
fn fast_backward_recovery_failure_reports_grad_fallbacks() {
    // A hostile recovery budget (k_max = 0) fails on every head: the
    // backward must be served by the dense fallback — bit-identical to
    // exact mode, since the fallback replays the forward's probs — and
    // the failure must be *visible* in grad_fallbacks, never a silent
    // divergence.
    let m = oracle_model(4030, 16);
    let mut rng = Rng::seeded(4031);
    let tokens = random_tokens(12, 16, &mut rng);
    let targets = random_tokens(12, 16, &mut rng);
    let rec = m.forward(&tokens, &AttentionBackend::Exact(ExactKernel::RowStream), true);
    let (_, dlogits) = m.lm_loss(&rec, &targets, usize::MAX);

    let engine = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 32 });
    let mut exact = m.zero_grads();
    let mode = AttnBackwardMode::Exact(ExactKernel::RowStream);
    m.backward_with_engine(&rec, &dlogits, None, &mut exact, &engine, &mode);

    let bad = AttnBackwardMode::Fast(FastGradConfig {
        recover: conv_basis::basis::RecoverConfig { k_max: 0, t: 1, delta: 1.0, eps: 0.0 },
        use_cache: false,
    });
    let mut fallback = m.zero_grads();
    m.backward_with_engine(&rec, &dlogits, None, &mut fallback, &engine, &bad);

    let snap = engine.metrics().snapshot();
    assert_eq!(snap.lm_backward_fallbacks, 4, "every (layer, head) job fell back");
    assert_eq!(snap.grad_fallbacks, 4, "reported on the shared gradient-lane counter");
    assert_grads_bit_identical(&exact, &fallback, "fallback-vs-exact");
}
