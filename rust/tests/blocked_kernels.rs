//! Kernel-equivalence oracle harness for the blocked exact kernels
//! (ISSUE 10).
//!
//! Two-level contract, mirrored from `attention::blocked`'s module
//! doc:
//!
//! 1. **Cross-family tolerance** — every blocked kernel (serving
//!    forward, training forward, decode, backward) matches its
//!    row-streamed oracle within the documented analytic bound
//!    `blocked_rtol(n) · ‖V‖∞`, on random AND adversarial inputs, at
//!    sizes straddling tile boundaries (n ∈ {8, 33, 64, 257} with
//!    `BLOCK` = 16: below one tile, past two tiles, exactly four
//!    tiles, sixteen tiles plus a ragged single-column tail). The
//!    blocked side is *more* robust than a naive oracle: it must
//!    survive logit magnitudes where an unstabilized softmax
//!    overflows to inf/NaN.
//! 2. **In-family bit-identity** — blocked decode replays blocked
//!    prefill's float-op order step for step (`assert_eq!`, not
//!    tolerance), the engine's blocked lanes are the library
//!    functions bit for bit, and engine-routed blocked jobs are
//!    bit-identical across worker counts 1/2/8.
//!
//! A central finite-difference check additionally pins the blocked
//! backward to the analytic gradient, independently of every other
//! kernel in the crate.

use conv_basis::attention::batched::{
    AttnJob, BatchedBackend, BatchedEngine, DecodeJob, DecodeOp, EngineConfig, EngineJob,
    EngineResult,
};
use conv_basis::attention::blocked::{
    attn_backward_blocked, blocked_attention_causal, blocked_decode_last_row, blocked_rtol,
    blocked_train_forward, causal_logits_row, BLOCK,
};
use conv_basis::attention::decode::exact_decode_last_row;
use conv_basis::attention::{exact_attention, ExactKernel, Mask};
use conv_basis::gradient::batched::{AttnBackwardJob, AttnBackwardMode};
use conv_basis::tensor::{linf_norm_mat, max_abs_diff, softmax, Matrix, Rng};
use std::sync::Arc;

/// Sizes straddling tile boundaries (see the module doc above).
const SIZES: [usize; 4] = [8, 33, 64, 257];

fn inputs(n: usize, d: usize, seed: u64, scale: f64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::seeded(seed);
    let q = Matrix::randn(n, d, &mut rng).scale(scale);
    let k = Matrix::randn(n, d, &mut rng).scale(scale);
    let v = Matrix::randn(n, d, &mut rng);
    (q, k, v)
}

/// The documented cross-family tolerance for one problem instance:
/// the relative bound scaled by the magnitude of the values the
/// softmax rows mix.
fn tol(n: usize, v: &Matrix) -> f64 {
    blocked_rtol(n) * linf_norm_mat(v).max(1.0)
}

#[test]
fn blocked_forward_matches_rowstream_oracle_at_tile_straddling_sizes() {
    assert_eq!(BLOCK, 16, "SIZES above were chosen to straddle the documented tile width");
    for (i, &n) in SIZES.iter().enumerate() {
        let (q, k, v) = inputs(n, 8, 900 + i as u64, 0.5);
        let got = blocked_attention_causal(&q, &k, &v);
        let want = exact_attention(&q, &k, &v, &Mask::causal(n));
        let err = max_abs_diff(&got, &want);
        let t = tol(n, &v);
        assert!(err <= t, "n={n}: blocked forward drifted {err:.3e} > {t:.3e}");
    }
}

#[test]
fn blocked_train_forward_probs_match_dense_softmax_rows() {
    for (i, &n) in SIZES.iter().enumerate() {
        let (q, k, v) = inputs(n, 6, 910 + i as u64, 0.5);
        let (y, probs) = blocked_train_forward(&q, &k, &v);
        // The training forward's y is the serving forward, bit for
        // bit: both run the same tile walk over the same inputs.
        assert_eq!(
            max_abs_diff(&y, &blocked_attention_causal(&q, &k, &v)),
            0.0,
            "n={n}: training y must be bit-identical to the serving forward"
        );
        let logits = q.matmul(&k.transpose());
        for r in 0..n {
            let want = softmax(&logits.row(r)[..=r]);
            for (j, w) in want.iter().enumerate() {
                let p = probs.row(r)[j];
                assert!(
                    (p - w).abs() <= blocked_rtol(n),
                    "n={n}: probs[{r},{j}] = {p:.17e} vs dense softmax {w:.17e}"
                );
            }
            for j in (r + 1)..n {
                assert_eq!(probs.row(r)[j], 0.0, "n={n}: probs[{r},{j}] above the diagonal");
            }
        }
    }
}

#[test]
fn blocked_decode_tracks_rowstream_and_bitmatches_blocked_reprefill() {
    // n = 41 walks the growing prefix across two tile boundaries
    // (16 and 32) with ragged tails on both sides of each.
    let (n, d) = (41, 5);
    let (q, k, v) = inputs(n, d, 920, 0.5);
    for i in 0..n {
        let len = i + 1;
        let kp = k.slice(0, len, 0, d);
        let vp = v.slice(0, len, 0, d);
        let h = causal_logits_row(q.row(i), &kp, len);
        let got = blocked_decode_last_row(&h, &vp);
        // In-family bit pin: decode replays the float-op order of a
        // blocked prefill of the same prefix, step for step.
        let qp = q.slice(0, len, 0, d);
        let full = blocked_attention_causal(&qp, &kp, &vp);
        assert_eq!(got, full.row(len - 1), "step {i}: blocked decode != blocked prefill bits");
        // Cross-family tolerance pin against the row-stream decode.
        let want = exact_decode_last_row(&h, &vp);
        let t = tol(len, &vp);
        for (j, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() <= t,
                "step {i}, col {j}: blocked decode drifted {:.3e} > {t:.3e}",
                (a - b).abs()
            );
        }
    }
}

#[test]
fn blocked_survives_logit_scales_that_overflow_unstabilized_exp() {
    let (n, d) = (64, 8);
    let mut rng = Rng::seeded(930);
    // randn·20 per side gives logits of magnitude ~d·400, far past
    // ~709.78 where a raw `exp` overflows f64 to inf. An unmaxed
    // softmax returns inf/inf = NaN here; both exact families must
    // return the convex combination regardless.
    let q = Matrix::randn(n, d, &mut rng).scale(20.0);
    let k = Matrix::randn(n, d, &mut rng).scale(20.0);
    let v = Matrix::ones(n, d);
    let got = blocked_attention_causal(&q, &k, &v);
    assert!(got.is_finite(), "blocked forward must survive huge logits");
    let t = tol(n, &v);
    for i in 0..n {
        for (j, &x) in got.row(i).iter().enumerate() {
            assert!(
                (x - 1.0).abs() <= t,
                "[{i},{j}]: a convex combination of ones must stay ~1, got {x:.17e}"
            );
        }
    }
    // Still agrees with the (stabilized) row-stream oracle.
    let want = exact_attention(&q, &k, &v, &Mask::causal(n));
    let err = max_abs_diff(&got, &want);
    assert!(err <= t, "adversarial scale: blocked drifted {err:.3e} > {t:.3e} from row-stream");
    // Decode at the same scale.
    let h = causal_logits_row(q.row(n - 1), &k, n);
    let row = blocked_decode_last_row(&h, &v);
    assert!(row.iter().all(|x| x.is_finite()), "blocked decode must survive huge logits");
    assert_eq!(row, got.row(n - 1), "decode/prefill bit pin holds at adversarial scale");
}

#[test]
fn blocked_backward_passes_central_finite_difference() {
    let (n, d) = (12, 4);
    let (q, k, v) = inputs(n, d, 940, 0.4);
    let mut rng = Rng::seeded(941);
    let w = Matrix::randn(n, d, &mut rng);
    // L(Q, K, V) = Σ_ij W_ij · Y_ij, so dL/dY = W.
    let loss = |q: &Matrix, k: &Matrix, v: &Matrix| -> f64 {
        let y = blocked_attention_causal(q, k, v);
        let mut l = 0.0;
        for i in 0..n {
            for j in 0..d {
                l += w.row(i)[j] * y.row(i)[j];
            }
        }
        l
    };
    let (_, probs) = blocked_train_forward(&q, &k, &v);
    let (dq, dk, dv) = attn_backward_blocked(&probs, &q, &k, &v, &w);
    let eps = 1e-5;
    let perturb = |m: &Matrix, r: usize, c: usize, delta: f64| -> Matrix {
        Matrix::from_fn(m.rows(), m.cols(), |i, j| {
            m.row(i)[j] + if (i, j) == (r, c) { delta } else { 0.0 }
        })
    };
    for (name, grad) in [("dq", &dq), ("dk", &dk), ("dv", &dv)] {
        for r in 0..n {
            for c in 0..d {
                let (lp, lm) = match name {
                    "dq" => (
                        loss(&perturb(&q, r, c, eps), &k, &v),
                        loss(&perturb(&q, r, c, -eps), &k, &v),
                    ),
                    "dk" => (
                        loss(&q, &perturb(&k, r, c, eps), &v),
                        loss(&q, &perturb(&k, r, c, -eps), &v),
                    ),
                    _ => (
                        loss(&q, &k, &perturb(&v, r, c, eps)),
                        loss(&q, &k, &perturb(&v, r, c, -eps)),
                    ),
                };
                let fd = (lp - lm) / (2.0 * eps);
                let g = grad.row(r)[c];
                assert!(
                    (fd - g).abs() <= 1e-6 + 1e-5 * g.abs().max(fd.abs()),
                    "{name}[{r},{c}]: finite diff {fd:.8e} vs analytic {g:.8e}"
                );
            }
        }
    }
}

#[test]
fn engine_blocked_backward_matches_rowstream_mode_within_tolerance() {
    // n = 57: three full tiles plus a ragged 9-column tail.
    let (n, dh) = (57, 6);
    let (q, k, v) = inputs(n, dh, 950, 0.3);
    let mut rng = Rng::seeded(951);
    let dout = Matrix::randn(n, dh, &mut rng);
    let (_, probs) = blocked_train_forward(&q, &k, &v);
    let probs = Arc::new(probs);
    let e = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 16 });
    let job = |key: u64, mode: AttnBackwardMode| {
        EngineJob::attn_backward(
            key,
            AttnBackwardJob {
                layer: 0,
                head: 0,
                q: q.clone(),
                k: k.clone(),
                v: v.clone(),
                dout: dout.clone(),
                probs: Some(probs.clone()),
                basis: None,
                mode,
            },
        )
    };
    let outs = e.submit(vec![
        job(1, AttnBackwardMode::Exact(ExactKernel::RowStream)),
        job(2, AttnBackwardMode::Exact(ExactKernel::Blocked)),
    ]);
    let rs = outs[0].result.clone().into_attn_backward();
    let bl = outs[1].result.clone().into_attn_backward();
    // Both modes consume the same probs; they differ only in
    // accumulation order, so a small multiple of the forward bound
    // covers the backward's extra reductions.
    let t = blocked_rtol(n) * 16.0;
    for (a, b, name) in [(&rs.dq, &bl.dq, "dq"), (&rs.dk, &bl.dk, "dk"), (&rs.dv, &bl.dv, "dv")] {
        let err = max_abs_diff(a, b);
        assert!(err <= t, "{name}: blocked backward drifted {err:.3e} > {t:.3e}");
    }
    // The engine's blocked lane is the library kernel, bit for bit.
    let (dq, dk, dv) = attn_backward_blocked(&probs, &q, &k, &v, &dout);
    assert_eq!(max_abs_diff(&bl.dq, &dq), 0.0, "engine dq != library dq");
    assert_eq!(max_abs_diff(&bl.dk, &dk), 0.0, "engine dk != library dk");
    assert_eq!(max_abs_diff(&bl.dv, &dv), 0.0, "engine dv != library dv");
}

#[test]
fn engine_blocked_jobs_bit_identical_across_worker_counts() {
    let mk_jobs = || -> Vec<EngineJob> {
        let mut rng = Rng::seeded(960);
        let mut jobs = Vec::new();
        for (i, &n) in [19usize, 48, 130].iter().enumerate() {
            let d = 4 + 2 * (i % 2);
            let q = Matrix::randn(n, d, &mut rng).scale(0.4);
            let k = Matrix::randn(n, d, &mut rng).scale(0.4);
            let v = Matrix::randn(n, d, &mut rng);
            let blocked = BatchedBackend::Exact(ExactKernel::Blocked);
            jobs.push(EngineJob::prefill(
                (10 + i) as u64,
                AttnJob::causal(0, i as u32, q.clone(), k.clone(), v.clone(), blocked.clone()),
            ));
            jobs.push(EngineJob::prefill(
                (20 + i) as u64,
                AttnJob::causal(0, i as u32, q.clone(), k.clone(), v.clone(), blocked)
                    .for_training(),
            ));
            jobs.push(EngineJob::decode(
                (30 + i) as u64,
                DecodeJob {
                    layer: 0,
                    head: i as u32,
                    state: None,
                    new_row: causal_logits_row(q.row(n - 1), &k, n),
                    v,
                    q: None,
                    k: None,
                    op: DecodeOp::Exact(ExactKernel::Blocked),
                },
            ));
        }
        jobs
    };
    let keys: Vec<u64> = mk_jobs().iter().map(|j| j.key).collect();
    let mut per_worker = Vec::new();
    for workers in [1usize, 2, 8] {
        let e = BatchedEngine::new(EngineConfig { workers, cache_capacity: 16 });
        let outs = e.submit(mk_jobs());
        assert_eq!(
            outs.iter().map(|o| o.key).collect::<Vec<_>>(),
            keys,
            "input order + key echo ({workers} workers)"
        );
        per_worker.push(outs);
    }
    let base = &per_worker[0];
    for (outs, workers) in per_worker[1..].iter().zip([2usize, 8]) {
        for (a, b) in outs.iter().zip(base) {
            match (&a.result, &b.result) {
                (EngineResult::Prefill(x), EngineResult::Prefill(y)) => {
                    assert_eq!(max_abs_diff(&x.y, &y.y), 0.0, "prefill bits ({workers} workers)");
                    match (&x.probs, &y.probs) {
                        (None, None) => {}
                        (Some(px), Some(py)) => assert_eq!(
                            max_abs_diff(px, py),
                            0.0,
                            "training probs bits ({workers} workers)"
                        ),
                        _ => panic!("probs presence flip ({workers} workers)"),
                    }
                }
                (EngineResult::Decode(x), EngineResult::Decode(y)) => {
                    assert_eq!(x.y_last, y.y_last, "decode bits ({workers} workers)");
                }
                _ => panic!("lane flip ({workers} workers)"),
            }
        }
    }
    // The engine's serving lane is the library kernel, bit for bit.
    let mut rng = Rng::seeded(960);
    let n = 19;
    let q = Matrix::randn(n, 4, &mut rng).scale(0.4);
    let k = Matrix::randn(n, 4, &mut rng).scale(0.4);
    let v = Matrix::randn(n, 4, &mut rng);
    let first = base[0].result.clone().into_prefill();
    assert_eq!(
        max_abs_diff(&first.y, &blocked_attention_causal(&q, &k, &v)),
        0.0,
        "engine blocked prefill != library kernel"
    );
}
