//! Decode-path correctness: autoregressive decode through the batched
//! engine must reproduce full re-prefill — bit-for-bit on the exact
//! backend, to recovery accuracy on the conv backend — and must do so
//! identically for any worker count.

use conv_basis::attention::batched::{
    AttnJob, BatchedBackend, BatchedEngine, DecodeJob, DecodeOp, EngineConfig, EngineJob,
};
use conv_basis::attention::decode::exact_attend_last;
use conv_basis::attention::rope::rope_structured_qk;
use conv_basis::attention::ExactKernel;
use conv_basis::model::{AttentionBackend, ModelConfig, Transformer};
use conv_basis::tensor::{dot, Matrix, Rng};

fn engine(workers: usize) -> BatchedEngine {
    BatchedEngine::new(EngineConfig { workers, cache_capacity: 256 })
}

fn attend(e: &BatchedEngine, jobs: Vec<AttnJob>) -> Vec<conv_basis::attention::batched::JobOutput> {
    e.submit(jobs.into_iter().enumerate().map(|(i, j)| EngineJob::prefill(i as u64, j)).collect())
        .into_iter()
        .map(|o| o.result.into_prefill())
        .collect()
}

fn decode(
    e: &BatchedEngine,
    jobs: Vec<DecodeJob>,
) -> Vec<conv_basis::attention::batched::DecodeOutput> {
    e.submit(jobs.into_iter().enumerate().map(|(i, j)| EngineJob::decode(i as u64, j)).collect())
        .into_iter()
        .map(|o| o.result.into_decode())
        .collect()
}

/// The ISSUE-2 acceptance property: T decode steps from a length-n
/// prefill bit-match a fresh length-(n+t) prefill forward at every
/// intermediate length, for thread counts 1, 2 and 8. (Per-head
/// bit-equality across worker counts is pinned at the engine level by
/// `decode_batch_is_deterministic_across_worker_counts` and
/// `prop_decode_batch_deterministic` in `tests/properties.rs`; here the
/// property is end-to-end through `Transformer::decode_step`, so every
/// head of every layer must agree for the logits to be bit-equal.)
#[test]
fn prop_decode_steps_bitmatch_full_prefill_across_thread_counts() {
    for case in 0..5u64 {
        let seed = 0xDEC0DE ^ (case * 2654435761);
        let mut rng = Rng::seeded(seed);
        let model = Transformer::new(&ModelConfig::tiny(32), &mut rng);
        let prompt: Vec<usize> = (0..3 + rng.below(6)).map(|_| 1 + rng.below(250)).collect();
        let feed: Vec<usize> = (0..4).map(|_| 1 + rng.below(250)).collect();

        let mut per_worker_logits: Vec<Vec<Vec<f64>>> = Vec::new();
        for workers in [1usize, 2, 8] {
            let e = engine(workers);
            let exact = AttentionBackend::Exact(ExactKernel::RowStream);
            let (mut sess, last) = model.prefill(&prompt, &exact, &e);
            let mut steps = vec![last];
            for &t in &feed {
                let logits = model.decode_step(std::slice::from_mut(&mut sess), &[t], &e);
                steps.push(logits.into_iter().next().unwrap());
            }
            per_worker_logits.push(steps);
        }
        // Bit-identical across worker counts…
        for other in &per_worker_logits[1..] {
            assert_eq!(
                other, &per_worker_logits[0],
                "worker count changed decode output (seed {seed})"
            );
        }
        // …and bit-identical to a fresh full prefill at every length.
        let mut toks = prompt.clone();
        let want = model.forward(&toks, &AttentionBackend::Exact(ExactKernel::RowStream), false);
        assert_eq!(
            per_worker_logits[0][0],
            want.logits.row(toks.len() - 1).to_vec(),
            "prefill logits diverged (seed {seed})"
        );
        for (i, &t) in feed.iter().enumerate() {
            toks.push(t);
            let want =
                model.forward(&toks, &AttentionBackend::Exact(ExactKernel::RowStream), false);
            assert_eq!(
                per_worker_logits[0][i + 1],
                want.logits.row(toks.len() - 1).to_vec(),
                "decode step {i} diverged from full re-prefill (seed {seed})"
            );
        }
    }
}

/// Conv decode over many appended tokens on structured (Toeplitz) Q/K:
/// seeded for free from the prefill's cached basis, drift-free growth,
/// and every step's output matches the exact last-row oracle.
#[test]
fn conv_decode_loop_stays_exact_and_seeds_from_prefill_cache() {
    let e = engine(2);
    let mut rng = Rng::seeded(77);
    let (n0, grow, d) = (24, 8, 6);
    let nf = n0 + grow;
    let (q_full, k_full) = rope_structured_qk(nf, d, 2, &mut rng);
    let q0 = q_full.slice(0, n0, 0, d);
    let k0 = k_full.slice(0, n0, 0, d);
    let v0 = Matrix::randn(n0, d, &mut rng);

    // Prefill through the engine: recovers + caches the basis.
    let outs = attend(&e, vec![AttnJob::causal(
        0,
        0,
        q0.clone(),
        k0.clone(),
        v0,
        BatchedBackend::Strided(1),
    )]);
    assert!(!outs[0].fell_back);

    // Seeding is a pure cache hit — no recovery work at decode start.
    let (seeded, hit) = e.seed_decode(0, 0, &q0, &k0, 1);
    assert!(hit, "prefill must have cached the basis");
    let mut state = Some(seeded);

    let v_full = Matrix::randn(nf, d, &mut rng);
    for step in 0..grow {
        let ncur = n0 + step;
        let new_row: Vec<f64> =
            (0..=ncur).map(|j| dot(q_full.row(ncur), k_full.row(j))).collect();
        let v = v_full.slice(0, ncur + 1, 0, d);
        let outs = decode(&e, vec![DecodeJob {
            layer: 0,
            head: 0,
            state: state.take(),
            new_row,
            v: v.clone(),
            q: Some(q_full.slice(0, ncur + 1, 0, d)),
            k: Some(k_full.slice(0, ncur + 1, 0, d)),
            op: DecodeOp::conv(1),
        }]);
        let out = outs.into_iter().next().unwrap();
        assert!(!out.rerecovered, "structured growth must stay drift-free (step {step})");
        assert!(!out.fell_back);
        let want = exact_attend_last(
            &q_full.slice(0, ncur + 1, 0, d),
            &k_full.slice(0, ncur + 1, 0, d),
            &v,
        );
        for (a, b) in out.y_last.iter().zip(&want) {
            assert!((a - b).abs() < 1e-8, "step {step}: {a} vs {b}");
        }
        state = out.state;
    }
    let snap = e.metrics().snapshot();
    assert_eq!(snap.decode_seed_hits, 1);
    assert_eq!(snap.decode_seed_misses, 0);
    assert_eq!(snap.decode_rerecoveries, 0);
    assert_eq!(snap.decode_steps, grow as u64);
}

/// KV-cache memory accounting (first ROADMAP slice): the
/// `decode_resident_bytes` gauge must equal the live sessions' resident
/// bytes after prefill, grow with every decode step, and return to zero
/// on retirement.
#[test]
fn decode_resident_bytes_gauge_tracks_session_lifecycle() {
    let mut rng = Rng::seeded(123);
    let model = Transformer::new(&ModelConfig::tiny(32), &mut rng);
    let e = engine(2);
    assert_eq!(e.metrics().snapshot().decode_resident_bytes, 0);

    let backend = AttentionBackend::ConvStrided(4);
    let (mut sess, _) = model.prefill(&[1, 2, 3, 4, 5, 6], &backend, &e);
    let after_prefill = e.metrics().snapshot().decode_resident_bytes;
    assert_eq!(after_prefill, sess.resident_bytes() as u64, "gauge == live session bytes");
    assert!(after_prefill > 0);

    let mut prev = after_prefill;
    for t in [7usize, 8, 9] {
        let _ = model.decode_step(std::slice::from_mut(&mut sess), &[t], &e);
        let now = e.metrics().snapshot().decode_resident_bytes;
        assert_eq!(now, sess.resident_bytes() as u64, "gauge tracks KV growth exactly");
        assert!(now > prev, "each appended token must add resident bytes");
        prev = now;
    }

    // A second session stacks on top…
    let (sess2, _) = model.prefill(&[9, 8, 7, 6], &backend, &e);
    let with_two = e.metrics().snapshot().decode_resident_bytes;
    assert_eq!(with_two, (sess.resident_bytes() + sess2.resident_bytes()) as u64);

    // …and retirement releases exactly each session's share.
    sess2.retire(e.metrics());
    assert_eq!(e.metrics().snapshot().decode_resident_bytes, sess.resident_bytes() as u64);
    sess.retire(e.metrics());
    assert_eq!(e.metrics().snapshot().decode_resident_bytes, 0, "all sessions retired");
}

/// Drift-triggered re-recovery, end-to-end through the model layer:
/// token-embedding Q/K is *not* conv-structured, so growing the cached
/// basis must quickly trip the drift tolerance and force re-recoveries,
/// while the decode output stays finite and the session keeps going.
#[test]
fn conv_model_decode_rerecovers_on_drift() {
    let mut rng = Rng::seeded(99);
    let model = Transformer::new(&ModelConfig::tiny(32), &mut rng);
    let e = engine(2);
    let backend = AttentionBackend::ConvStrided(4);
    let (mut sess, last) = model.prefill(&[1, 2, 3, 4, 5, 6, 7, 8], &backend, &e);
    assert!(last.iter().all(|x| x.is_finite()));
    for t in [9usize, 10, 11, 12] {
        let logits = model.decode_step(std::slice::from_mut(&mut sess), &[t], &e);
        assert!(logits[0].iter().all(|x| x.is_finite()));
    }
    assert_eq!(sess.len(), 12);
    let snap = e.metrics().snapshot();
    let per_step = (model.cfg.n_layers * model.cfg.n_heads) as u64;
    assert_eq!(snap.decode_steps, 4 * per_step);
    assert_eq!(snap.decode_seed_hits, per_step, "prefill seeds all states from its cache");
    assert!(
        snap.decode_rerecoveries >= 1,
        "unstructured Q/K growth must trip the drift tolerance at least once \
         (re-recoveries = {})",
        snap.decode_rerecoveries
    );
}
