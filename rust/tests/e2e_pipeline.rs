//! End-to-end pipeline tests: model training with conv-basis inference
//! swap (the Figure 4 protocol, scaled down), and coordinator serving
//! over a workload trace.

use conv_basis::attention::batched::{BatchedEngine, EngineConfig};
use conv_basis::attention::ExactKernel;
use conv_basis::coordinator::{
    run_trace, BatcherConfig, RouterConfig, Server, ServerConfig,
};
use conv_basis::data::{SentimentDataset, WorkloadConfig, WorkloadTrace};
use conv_basis::model::{
    eval_classifier, train_classifier, AttentionBackend, ModelConfig, TrainConfig,
};
use conv_basis::tensor::rel_fro_error;

#[test]
fn figure4_protocol_small() {
    // Train with exact attention; evaluate with conv-basis attention at
    // increasing k — relative error must fall and accuracy must rise
    // toward the exact backend's (the Figure 4 shape, at test scale).
    let seq = 48;
    let mcfg = ModelConfig {
        vocab_size: 260,
        d_model: 16,
        n_heads: 2,
        n_layers: 2,
        d_ff: 32,
        max_seq: seq,
    };
    let ds = SentimentDataset::generate(80, 30, 11);
    let tcfg = TrainConfig { steps: 80, lr: 3e-3, seq_len: seq, batch: 4, log_every: 40, seed: 12 };
    let (model, log) = train_classifier(&mcfg, &tcfg, &ds);
    assert!(log.losses.last().unwrap().1 < log.losses.first().unwrap().1);

    let tok = conv_basis::data::ByteTokenizer::new();
    let sample = tok.encode_for_classification(&ds.test[0].text, seq);
    let exact_rec = model.forward(&sample, &AttentionBackend::Exact(ExactKernel::RowStream), false);

    let mut errs = Vec::new();
    for k in [1usize, 4, seq] {
        let backend = if k == seq {
            AttentionBackend::ConvBasis(conv_basis::basis::RecoverConfig::exact(seq))
        } else {
            AttentionBackend::conv_with_k(k, seq)
        };
        let rec = model.forward(&sample, &backend, false);
        let err = rel_fro_error(&exact_rec.final_hidden, &rec.final_hidden);
        errs.push((k, err));
    }
    // Largest k is (numerically) exact.
    let (_, err_full) = *errs.last().unwrap();
    assert!(err_full < 1e-10, "full-k error = {err_full} ({errs:?})");
    // The Figure 4 shape: error decreases monotonically as k grows.
    for w in errs.windows(2) {
        assert!(
            w[1].1 <= w[0].1 + 1e-9,
            "error must not increase with k: {errs:?}"
        );
    }

    // Accuracy with full-k conv equals exact accuracy.
    let acc_exact =
        eval_classifier(&model, &ds.test, seq, &AttentionBackend::Exact(ExactKernel::RowStream));
    let acc_conv = eval_classifier(
        &model,
        &ds.test,
        seq,
        &AttentionBackend::ConvBasis(conv_basis::basis::RecoverConfig::exact(seq)),
    );
    assert!((acc_exact - acc_conv).abs() < 1e-9, "{acc_exact} vs {acc_conv}");
}

#[test]
fn coordinator_serves_mixed_trace_with_conv_speedup_metrics() {
    let server = Server::start(ServerConfig {
        router: RouterConfig { exact_below: 96, k_frac: 0.05, k_cap: 16, ..Default::default() },
        batcher: BatcherConfig { max_batch: 4, max_wait: std::time::Duration::from_millis(1) },
        workers: 3,
        cache_capacity: 32,
        lowrank_degree: 2,
        gen: None,
    });
    let trace = WorkloadTrace::generate(
        60,
        &WorkloadConfig {
            rate_per_s: 50_000.0,
            len_buckets: [48, 64, 128, 192],
            len_weights: [0.3, 0.3, 0.2, 0.2],
            d_model: 8,
        },
        21,
    );
    let resps = run_trace(&server, &trace, 0.0);
    assert_eq!(resps.len(), 60);
    let metrics = server.shutdown();
    let snap = metrics.snapshot();
    assert_eq!(snap.requests_completed, 60);
    // Both backends exercised by the mixed trace.
    assert!(snap.exact_requests > 0, "no exact requests");
    assert!(snap.conv_requests > 0, "no conv requests");
    // Latencies recorded.
    assert_eq!(snap.e2e.count, 60);
    assert!(snap.e2e.p50_us > 0.0);
    // Every response finite.
    for r in &resps {
        assert!(r.y.is_finite(), "response {} not finite", r.id);
    }
}

#[test]
fn trained_model_batched_forward_matches_singles_end_to_end() {
    // Train a small LM, then run a batch of prompts through
    // `forward_batch` (all heads of all sequences per layer in one
    // engine call) and check it reproduces the per-sequence forward
    // bit-for-bit, for both the exact and the conv-strided backend.
    let mcfg = ModelConfig {
        vocab_size: 260,
        d_model: 16,
        n_heads: 2,
        n_layers: 2,
        d_ff: 32,
        max_seq: 48,
    };
    let tcfg = TrainConfig { steps: 20, lr: 3e-3, seq_len: 48, batch: 2, log_every: 10, seed: 8 };
    let (model, _) = conv_basis::model::train_lm(&mcfg, &tcfg, 3000);
    let engine = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 64 });
    let prompts: Vec<Vec<usize>> = ["conv basis", "attention is", "fft"]
        .iter()
        .map(|s| s.bytes().map(|b| b as usize).collect())
        .collect();
    for backend in
        [AttentionBackend::Exact(ExactKernel::RowStream), AttentionBackend::conv_with_k(4, 48)]
    {
        let singles: Vec<_> = prompts.iter().map(|p| model.forward(p, &backend, false)).collect();
        let batched = model.forward_batch(&prompts, &backend, &engine);
        for (b, s) in batched.iter().zip(&singles) {
            let err = conv_basis::tensor::max_abs_diff(&b.logits, &s.logits);
            assert_eq!(err, 0.0, "batched and single forward diverged");
        }
    }
    // The engine actually batched: one call per (layer, backend-pass).
    let snap = engine.metrics().snapshot();
    assert_eq!(snap.batched_calls, 2 * mcfg.n_layers as u64);
    assert_eq!(
        snap.batched_jobs,
        2 * (mcfg.n_layers * mcfg.n_heads * prompts.len()) as u64
    );
}

#[test]
fn lm_training_then_conv_generation_consistency() {
    // Train a small LM, then check next-token distributions under exact
    // vs exact-config conv attention agree (greedy tokens identical).
    let mcfg = ModelConfig {
        vocab_size: 260,
        d_model: 16,
        n_heads: 2,
        n_layers: 1,
        d_ff: 32,
        max_seq: 32,
    };
    let tcfg = TrainConfig { steps: 30, lr: 3e-3, seq_len: 32, batch: 2, log_every: 15, seed: 5 };
    let (model, _) = conv_basis::model::train_lm(&mcfg, &tcfg, 3000);
    let prompt: Vec<usize> = "the model computes".bytes().map(|b| b as usize).collect();
    let exact = model.forward(&prompt, &AttentionBackend::Exact(ExactKernel::RowStream), false);
    let conv = model.forward(
        &prompt,
        &AttentionBackend::ConvBasis(conv_basis::basis::RecoverConfig::exact(prompt.len())),
        false,
    );
    let last = prompt.len() - 1;
    let argmax = |logits: &conv_basis::tensor::Matrix| {
        logits
            .row(last)
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    };
    assert_eq!(argmax(&exact.logits), argmax(&conv.logits));
}
