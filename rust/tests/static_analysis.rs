//! The repo-invariant lint, turned on itself:
//!
//! * the shipped `src/` tree lints clean under the shipped
//!   `lint.allow`, with zero stale allowlist entries;
//! * every fixture under `lint-fixtures/` reproduces its
//!   `// lint-expect: rule@line` markers exactly — rule id, file, and
//!   line — so a rule that drifts (or a fixture that moves a line)
//!   fails here before it fails confusingly in CI;
//! * allowlist matching is substring-scoped and unused entries are
//!   surfaced.
//!
//! The same checks gate CI as `cargo run --bin lint -- --self-test`
//! followed by the tree pass, *before* the test step.

use conv_basis::lintpass::{self, AllowEntry};
use std::path::PathBuf;

fn manifest() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn shipped_allowlist() -> Vec<AllowEntry> {
    let text = std::fs::read_to_string(manifest().join("lint.allow")).expect("rust/lint.allow");
    lintpass::parse_allowlist(&text).expect("shipped allowlist parses")
}

#[test]
fn shipped_tree_lints_clean_with_shipped_allowlist() {
    let allow = shipped_allowlist();
    let report = lintpass::lint_tree(&manifest().join("src"), &allow).expect("walk src");
    assert!(
        report.is_clean(),
        "determinism-lint violations in the shipped tree:\n{}",
        report.violations.iter().map(|v| format!("  {v}\n")).collect::<String>()
    );
    assert!(
        report.unused_allow.is_empty(),
        "stale allowlist entries (delete them from rust/lint.allow): {:?}",
        report.unused_allow.iter().map(|&i| (&allow[i].rule, &allow[i].file)).collect::<Vec<_>>()
    );
    // Sanity: the walk actually covered the crate, not an empty dir.
    assert!(report.files_scanned > 30, "only {} files scanned", report.files_scanned);
}

#[test]
fn shipped_allowlist_is_load_bearing_and_tight() {
    // Without the allowlist the tree must be dirty (otherwise the
    // allowlist is dead weight), and every raw violation must be
    // covered by some shipped (rule, file) entry — no surprises hiding
    // behind a broad match.
    let raw = lintpass::lint_tree(&manifest().join("src"), &[]).expect("walk src");
    assert!(!raw.violations.is_empty(), "allowlist is dead weight — delete rust/lint.allow?");
    let allow = shipped_allowlist();
    for v in &raw.violations {
        assert!(
            allow.iter().any(|a| a.rule == v.rule && a.file == v.file),
            "violation not covered by any shipped allowlist entry: {v}"
        );
    }
}

#[test]
fn fixtures_reproduce_their_markers() {
    let failures = lintpass::self_test(&manifest().join("lint-fixtures")).expect("walk fixtures");
    assert!(failures.is_empty(), "lint self-test failures:\n{failures:#?}");
}

#[test]
fn fixtures_exact_rule_file_and_line() {
    // One seeded violation per rule, plus one intentionally clean file
    // (coordinator/clean.rs) pinning the false-positive behavior —
    // asserted down to the exact (rule, file, line) triple.
    let report =
        lintpass::lint_tree(&manifest().join("lint-fixtures"), &[]).expect("walk fixtures");
    let got: Vec<(&str, &str, usize)> =
        report.violations.iter().map(|v| (v.rule, v.file.as_str(), v.line)).collect();
    assert_eq!(
        got,
        vec![
            ("wall-clock", "conv/timing.rs", 7),
            ("metrics-unbounded-push", "coordinator/metrics.rs", 10),
            ("request-path-unwrap", "coordinator/net.rs", 7),
            ("sync-facade", "fft/planner.rs", 6),
            ("hash-iter", "gradient/assemble.rs", 6),
        ]
    );
    assert_eq!(report.files_scanned, 6, "all fixtures (including the clean one) were scanned");
}

#[test]
fn allowlist_substring_scopes_the_exemption() {
    let hit = AllowEntry {
        rule: "request-path-unwrap".into(),
        file: "coordinator/net.rs".into(),
        substring: "parse::<u64>()".into(),
        note: "test".into(),
    };
    let report = lintpass::lint_tree(&manifest().join("lint-fixtures"), &[hit]).expect("walk");
    assert!(
        report.violations.iter().all(|v| v.rule != "request-path-unwrap"),
        "matching substring must exempt the seeded unwrap"
    );
    assert!(report.unused_allow.is_empty());

    let miss = AllowEntry {
        rule: "request-path-unwrap".into(),
        file: "coordinator/net.rs".into(),
        substring: "no-such-text".into(),
        note: "test".into(),
    };
    let report = lintpass::lint_tree(&manifest().join("lint-fixtures"), &[miss]).expect("walk");
    assert!(
        report.violations.iter().any(|v| v.rule == "request-path-unwrap"),
        "non-matching substring must not exempt"
    );
    assert_eq!(report.unused_allow, vec![0], "the miss entry is reported stale");
}
