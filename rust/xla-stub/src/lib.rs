//! **API-surface stub** of the external `xla` crate.
//!
//! The offline build image cannot vendor the real `xla` crate through a
//! registry, but the `pjrt`-gated code in `src/runtime/mod.rs` must not
//! rot silently — CI compiles it against this stub
//! (`cargo check --features pjrt`). Every type/method the runtime
//! touches exists here with the real crate's signatures; every
//! constructor fails at *runtime* with [`Error::Stub`], so a stub build
//! degrades exactly like the feature-off build (`PjrtRuntime::cpu()`
//! returns an error) instead of lying about having a device.
//!
//! To run real PJRT, point this path dependency at a vendored copy of
//! the actual crate (same package name) — no source changes needed.

use std::path::Path;

/// Error type mirroring `xla::Error` (Display + Debug are all the
/// runtime uses).
#[derive(Debug)]
pub enum Error {
    /// Raised by every stub entry point.
    Stub(&'static str),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Stub(msg) => write!(f, "xla stub: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

const STUB: &str = "this build links the in-tree xla API stub — vendor the real `xla` crate \
                    (replace the rust/xla-stub path dependency) to execute PJRT artifacts";

/// Host-side literal (tensor) handle.
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::Stub(STUB))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, Error> {
        Err(Error::Stub(STUB))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::Stub(STUB))
    }
}

/// Device-side buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::Stub(STUB))
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        Err(Error::Stub(STUB))
    }
}

/// An XLA computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::Stub(STUB))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::Stub(STUB))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::Stub(STUB))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_stub() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
    }
}
