//! Lint fixture: `hash-iter` — a HashMap in a deterministic module.
//! The self-test asserts exactly the marker below, rule and line.
// lint-expect: hash-iter@6

#[allow(dead_code)]
fn assemble(parts: Vec<(usize, f64)>) -> std::collections::HashMap<usize, f64> {
    parts.into_iter().collect()
}
