//! Lint fixture: `sync-facade` — raw `std::sync` in a module that must
//! import its primitives through `crate::sync` (the loom swap point).
// lint-expect: sync-facade@6

#[allow(dead_code)]
fn read_plan_count(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().expect("fixture")
}
