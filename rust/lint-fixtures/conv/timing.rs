//! Lint fixture: `wall-clock` — Instant::now in a kernel module.
//! Kernel results must be pure functions of inputs, never of time.
// lint-expect: wall-clock@7

#[allow(dead_code)]
fn timed_apply(xs: &[f64]) -> (f64, std::time::Duration) {
    let t0 = std::time::Instant::now();
    (xs.iter().sum(), t0.elapsed())
}
