//! Lint fixture: intentionally clean — every banned token below lives
//! in a comment, a string literal, or a `#[cfg(test)]` item, pinning
//! the lint's false-positive behavior.
// lint-expect: none

/// Docs may mention HashMap, Instant::now, std::sync, or .unwrap().
#[allow(dead_code)]
fn describe() -> &'static str {
    "HashMap and std::thread inside a string are payload, not code"
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn helper_uses_test_only_types() {
        let mut m: HashMap<u32, u32> = HashMap::new();
        m.insert(1, 2);
        assert_eq!(m.remove(&1).unwrap(), 2);
    }
}
