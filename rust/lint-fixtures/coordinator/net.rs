//! Lint fixture: `request-path-unwrap` — a bare `.unwrap()` on the
//! request path; `.expect("…")` and `.unwrap_or` are the audited forms.
// lint-expect: request-path-unwrap@7

#[allow(dead_code)]
fn parse_id(line: &str) -> u64 {
    line.trim().parse::<u64>().unwrap()
}

#[allow(dead_code)]
fn parse_id_audited(line: &str) -> u64 {
    line.trim().parse::<u64>().expect("fixture: the audited form does not trip the rule")
}
