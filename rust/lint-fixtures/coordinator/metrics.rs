//! Lint fixture: `metrics-unbounded-push` — a `.push(` with no
//! LATENCY_RESERVOIR_CAP token within the two lines above it.
// lint-expect: metrics-unbounded-push@10

#[allow(dead_code)]
fn record(samples: &mut Vec<f64>, x: f64) {
    // No cap guard in the two lines above the push: the reservoir
    // could grow without bound while the metrics mutex is held.
    let scaled = x * 2.0;
    samples.push(scaled);
}

const LATENCY_RESERVOIR_CAP: usize = 4096;

#[allow(dead_code)]
fn record_guarded(samples: &mut Vec<f64>, x: f64) {
    if samples.len() < LATENCY_RESERVOIR_CAP {
        samples.push(x);
    }
}
