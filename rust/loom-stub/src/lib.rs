//! Offline stand-in for the [`loom`](https://docs.rs/loom) model
//! checker, mirroring the `rust/xla-stub` pattern: the image cannot
//! vendor crates, so `--cfg loom` builds resolve the `loom` path
//! dependency to this crate instead.
//!
//! The re-exported types are the `std` originals under loom's module
//! layout, so every model in `rust/tests/loom_models.rs` compiles and
//! *runs* — but [`model`] degrades from exhaustive interleaving
//! exploration (loom's DPOR scheduler) to a seedless stress loop: the
//! closure is re-run [`model::iterations`] times under the OS
//! scheduler. A lost wakeup therefore shows up as a hang (caught by
//! the CI job timeout) or an assertion failure, not as a minimal
//! counterexample trace. Pointing the `[target.'cfg(loom)']` path
//! dependency in `rust/Cargo.toml` at a vendored real loom upgrades
//! every model to exhaustive checking with no source changes.
//!
//! Surface notes vs real loom:
//! * `sync::mpsc` and `thread::sleep` are stub extensions — real loom
//!   models neither. Only the worker-pool model uses mpsc (the pool's
//!   channel is its protocol); no model calls `sleep`.
//! * Real loom's atomics lack `Default` and `const fn new`; the
//!   modules behind `crate::sync` only construct atomics at runtime,
//!   so this does not bite, but new code should keep it in mind.

pub mod sync {
    pub use std::sync::{mpsc, Arc, Barrier, Condvar, Mutex, MutexGuard, RwLock};

    pub mod atomic {
        pub use std::sync::atomic::*;
    }
}

pub mod thread {
    pub use std::thread::{sleep, spawn, yield_now, JoinHandle};
}

pub mod model {
    /// How many times [`super::model`] re-runs its closure. Tunable via
    /// `LOOM_STUB_ITERS` (default 64); the loom CI job raises it.
    pub fn iterations() -> usize {
        std::env::var("LOOM_STUB_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(64)
    }
}

/// Run a concurrency model. Real loom explores every feasible
/// interleaving; this stub stress-loops the closure under the OS
/// scheduler (see crate docs for what that weakens).
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    for _ in 0..model::iterations() {
        f();
    }
}
