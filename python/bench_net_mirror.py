"""NumPy mirror of ``rust/src/bin/loadgen.rs`` (the serving sweep).

The Rust loadgen is the source of truth, but some build images carry no
Rust toolchain; this mirror reproduces the *same serving shape* —
a TCP front-end with newline-delimited flat-JSON framing, a scheduler
thread running token-budget admission (the TGI trio:
``max_batch_prefill_tokens`` / ``max_batch_total_tokens`` /
``waiting_served_ratio`` with a ``max_waiting_steps`` starvation
valve), bounded queueing with busy shedding, and per-step token
streaming — over a NumPy stand-in for the model: per (layer, head) the
k=1 conv decode-step cost (cached-basis grow + banded weighted sum,
``O(k*n + n*d)``) and the conv FFT prefill apply, mirroring
``ModelConfig::tiny`` (d_model 32, 2 layers, 2 heads).

Closed-loop clients per cell of the sweep (batch x prompt_len x
decode_len) connect over real sockets, stream their tokens, and
measure TTFT and end-to-end latency off the wire — the same protocol
and measurement points as the Rust binary.

Run: ``python3 python/bench_net_mirror.py [--smoke] [--out PATH]``
(default out: ``BENCH_PR6.json``, schema ``bench_pr6/v1`` with
``"source": "numpy-mirror"`` so readers know which harness produced
the numbers).
"""

import json
import socket
import socketserver
import sys
import threading
import time
from collections import deque

import numpy as np

D_MODEL = 32
N_LAYERS = 2
N_HEADS = 2
D_HEAD = D_MODEL // N_HEADS

ADMISSION = {
    "max_batch_prefill_tokens": 4096,
    "max_batch_total_tokens": 16384,
    "waiting_served_ratio": 1.2,
    "max_waiting_steps": 4,
    "max_queue": 256,
}


class Session:
    """One in-flight generation: per-(layer, head) cached conv basis."""

    def __init__(self, req, wfile, lock):
        self.req = req
        self.wfile = wfile
        self.wlock = lock
        self.generated = []
        rng = np.random.default_rng(req["id"] + 1)
        n = len(req["prompt"])
        self.n = n
        # Per (layer, head): Toeplitz generator g, post-exp basis b, V.
        self.heads = []
        for _ in range(N_LAYERS * N_HEADS):
            g = rng.normal(scale=0.5, size=n)
            self.heads.append(
                {"g": g, "b": np.exp(g), "v": rng.normal(size=(n, D_HEAD))}
            )

    def prefill(self):
        # Conv FFT apply per (layer, head): the Algorithm-1 "apply" half.
        for h in self.heads:
            n = self.n
            fb = np.fft.rfft(h["b"], 2 * n)
            for c in range(D_HEAD):
                np.fft.irfft(fb * np.fft.rfft(h["v"][:, c], 2 * n))[:n]

    def decode_step(self, rng):
        # Cached-basis conv step per (layer, head): O(k*n + n*d).
        for h in self.heads:
            gnew = rng.normal(scale=0.5)
            h["g"] = np.append(h["g"], gnew)
            h["b"] = np.append(h["b"], np.exp(gnew))
            h["v"] = np.vstack([h["v"], rng.normal(size=(1, D_HEAD))])
            w = h["b"][::-1]
            (w @ h["v"]) / h["b"].sum()
        self.n += 1
        tok = int(rng.integers(1, 256))
        self.generated.append(tok)
        return tok


def write_line(wfile, wlock, obj):
    try:
        with wlock:
            wfile.write((json.dumps(obj, separators=(",", ":")) + "\n").encode())
            wfile.flush()
    except (OSError, ValueError):
        pass  # dead/closed client: it just stops receiving


class Scheduler:
    """Mirror of the generation scheduler + AdmissionQueue pair."""

    def __init__(self):
        self.cv = threading.Condition()
        self.waiting = deque()
        self.shutting = False
        self.shed = 0
        self.thread = threading.Thread(target=self.run, daemon=True)
        self.thread.start()

    def submit(self, req, wfile, wlock):
        with self.cv:
            if self.shutting or len(self.waiting) >= ADMISSION["max_queue"]:
                self.shed += 1
                write_line(wfile, wlock, {"ev": "busy", "id": req["id"]})
                return
            self.waiting.append((req, wfile, wlock))
            self.cv.notify_all()

    def shutdown(self):
        with self.cv:
            self.shutting = True
            self.cv.notify_all()
        self.thread.join()

    def admit(self, sessions, steps_since_admit):
        with self.cv:
            if not self.waiting:
                return []
            if sessions and steps_since_admit < ADMISSION["max_waiting_steps"]:
                need = int(
                    np.ceil(ADMISSION["waiting_served_ratio"] * len(sessions))
                )
                if len(self.waiting) < need:
                    return []
            out, prefill = [], 0
            total = sum(
                s.n + s.req["max_new_tokens"] - len(s.generated) for s in sessions
            )
            while self.waiting:
                req, wfile, wlock = self.waiting[0]
                p = len(req["prompt"])
                if sessions or out:
                    if prefill + p > ADMISSION["max_batch_prefill_tokens"]:
                        break
                    if (
                        total + p + req["max_new_tokens"]
                        > ADMISSION["max_batch_total_tokens"]
                    ):
                        break
                prefill += p
                total += p + req["max_new_tokens"]
                out.append(self.waiting.popleft())
            return out

    def run(self):
        rng = np.random.default_rng(7)
        sessions = []
        steps_since_admit = 0
        while True:
            if not sessions:
                with self.cv:
                    while not self.waiting and not self.shutting:
                        self.cv.wait()  # event-driven: no idle polling
                    if self.shutting and not self.waiting:
                        return
            for req, wfile, wlock in self.admit(sessions, steps_since_admit):
                s = Session(req, wfile, wlock)
                s.prefill()
                tok = s.decode_step(rng)  # first token rides the prefill
                write_line(wfile, wlock, {"ev": "token", "id": req["id"], "index": 0, "token": tok})
                sessions.append(s)
                steps_since_admit = 0
            retired = []
            for s in sessions:
                tok = s.decode_step(rng)
                write_line(
                    s.wfile,
                    s.wlock,
                    {"ev": "token", "id": s.req["id"], "index": len(s.generated) - 1, "token": tok},
                )
                if len(s.generated) >= s.req["max_new_tokens"]:
                    retired.append(s)
            steps_since_admit += 1
            for s in retired:
                sessions.remove(s)
                write_line(
                    s.wfile,
                    s.wlock,
                    {
                        "ev": "done",
                        "id": s.req["id"],
                        "prompt_len": len(s.req["prompt"]),
                        "decode_steps": len(s.generated),
                        "tokens": s.generated,
                    },
                )


class Handler(socketserver.StreamRequestHandler):
    disable_nagle_algorithm = True  # streamed token lines must not sit in Nagle

    def handle(self):
        wlock = threading.Lock()
        for raw in self.rfile:
            line = raw.decode().strip()
            if not line:
                continue
            req = json.loads(line)
            if req.get("op") == "generate":
                self.server.scheduler.submit(req, self.wfile, wlock)
            else:
                write_line(self.wfile, wlock, {"ev": "error", "msg": "unknown op"})


def client_loop(addr, conn_id, prompt_len, decode_len, iters, out):
    sock = socket.create_connection(addr)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    rfile = sock.makefile("rb")
    prompt = [((conn_id * 131 + j * 17) % 255) + 1 for j in range(prompt_len)]
    lats, tokens, shed = [], 0, 0
    for i in range(iters):
        t0 = time.perf_counter()
        sock.sendall(
            (
                json.dumps(
                    {"op": "generate", "id": i, "prompt": prompt, "max_new_tokens": decode_len},
                    separators=(",", ":"),
                )
                + "\n"
            ).encode()
        )
        ttft = None
        for raw in rfile:
            ev = json.loads(raw)
            if ev["ev"] == "token":
                tokens += 1
                if ttft is None:
                    ttft = (time.perf_counter() - t0) * 1e6
            elif ev["ev"] == "done":
                lats.append((ttft, (time.perf_counter() - t0) * 1e6))
                break
            elif ev["ev"] == "busy":
                shed += 1
                break
    sock.close()
    out.append((lats, tokens, shed))


def pct(xs, q):
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(len(xs) - 1, round(q * (len(xs) - 1)))]


def run_cell(batch, prompt_len, decode_len, iters):
    server = socketserver.ThreadingTCPServer(("127.0.0.1", 0), Handler)
    server.daemon_threads = True
    server.scheduler = Scheduler()
    threading.Thread(target=server.serve_forever, daemon=True).start()
    addr = server.server_address

    t0 = time.perf_counter()
    out = []
    threads = [
        threading.Thread(target=client_loop, args=(addr, c, prompt_len, decode_len, iters, out))
        for c in range(batch)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0

    server.scheduler.shutdown()
    server.shutdown()
    server.server_close()

    lats = [l for ls, _, _ in out for l in ls]
    tokens = sum(t for _, t, _ in out)
    shed = sum(s for _, _, s in out)
    return {
        "batch": batch,
        "prompt_len": prompt_len,
        "decode_len": decode_len,
        "requests": len(lats),
        "tokens": tokens,
        "wall_s": round(wall, 6),
        "tokens_per_s": round(tokens / wall, 3),
        "ttft_p50_us": round(pct([l[0] for l in lats], 0.5), 1),
        "e2e_p50_us": round(pct([l[1] for l in lats], 0.5), 1),
        "e2e_p95_us": round(pct([l[1] for l in lats], 0.95), 1),
        "shed": shed,
    }


def main():
    smoke = "--smoke" in sys.argv
    out_path = "BENCH_PR6.json"
    if "--out" in sys.argv:
        out_path = sys.argv[sys.argv.index("--out") + 1]
    if smoke:
        batches, prompts, decodes, iters = [1, 2], [8, 16], [4], 2
    else:
        batches, prompts, decodes, iters = [1, 4, 8], [16, 64, 256], [8, 32], 3

    cells = []
    print("# Closed-loop TCP load sweep — NumPy mirror (k=1 conv decode, streaming)")
    print("| batch | prompt | decode | req | tok/s | ttft p50 µs | e2e p50 µs | e2e p95 µs | shed |")
    print("|---|---|---|---|---|---|---|---|---|")
    for b in batches:
        for p in prompts:
            for d in decodes:
                c = run_cell(b, p, d, iters)
                cells.append(c)
                print(
                    f"| {b} | {p} | {d} | {c['requests']} | {c['tokens_per_s']:.1f} "
                    f"| {c['ttft_p50_us']:.0f} | {c['e2e_p50_us']:.0f} "
                    f"| {c['e2e_p95_us']:.0f} | {c['shed']} |"
                )

    doc = {"schema": "bench_pr6/v1", "source": "numpy-mirror", "smoke": smoke, "cells": cells}
    with open(out_path, "w") as f:
        json.dump(doc, f, separators=(",", ":"))
        f.write("\n")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
