"""NumPy mirror of ``benches/blocked_attn.rs`` (PR 10, blocked kernels).

The Rust bench is the source of truth, but some build images carry no
Rust toolchain; this mirror reproduces the *same strategies* with the
same asymptotics so the blocked-kernel cost story stays measured
anywhere NumPy exists. Per causal prefill at length n, head dim d:

* ``row-stream fwd`` — materialize the full n x n logits, dense
                       stabilized softmax rows, n x n probs @ V
                       (the ``exact_attention`` cost shape);
* ``blocked fwd``    — flash-style online softmax over column tiles
                       of the causal prefix only: running (m, s, acc)
                       per row, no n x n temporaries
                       (``blocked_attention_causal``);
* ``row-stream bwd`` — matrix-form backward over all n^2 entries
                       (P^T dout, dout V^T, dS, two n x n matmuls);
* ``blocked bwd``    — the same math walked per row-block over the
                       causal prefix only (``attn_backward_blocked``:
                       half the flops, tile-local temporaries);
* ``decode``         — one last-row step, O(n*d) both ways (parity
                       tracking, not a win).

Tile sizes differ from the Rust ``BLOCK = 16`` on purpose: Rust tiles
target L1 cache lines; the mirror tiles (128-256) amortize NumPy call
overhead instead. The asymptotics and the causal-half-flops story are
identical.

The accuracy check mirrors the documented contract of
``rust/src/attention/blocked.rs``: blocked output within
``blocked_rtol(n) * ||V||_inf`` of the row-stream oracle, where
``blocked_rtol(n) = 64 * n * eps``.

Run: ``python3 python/bench_blocked_mirror.py`` (prints markdown
tables; numbers land in EXPERIMENTS.md, clearly labelled as the
mirror, not the Rust bench).
"""

import time

import numpy as np

D = 8
NS = [256, 1024, 4096]
ITERS = 3
RB, CB = 128, 256  # mirror row-block / column-tile sizes


def blocked_rtol(n):
    return 64.0 * n * np.finfo(np.float64).eps


def rowstream_fwd(q, k, v):
    logits = q @ k.T
    n = q.shape[0]
    mask = np.tril(np.ones((n, n), dtype=bool))
    logits = np.where(mask, logits, -np.inf)
    w = np.exp(logits - logits.max(axis=1, keepdims=True))
    return (w @ v) / w.sum(axis=1, keepdims=True)


def blocked_fwd(q, k, v):
    n, d = q.shape
    y = np.empty((n, d))
    for r0 in range(0, n, RB):
        r1 = min(r0 + RB, n)
        qb = q[r0:r1]
        m = np.full(r1 - r0, -np.inf)
        s = np.zeros(r1 - r0)
        acc = np.zeros((r1 - r0, d))
        for c0 in range(0, r1, CB):
            c1 = min(c0 + CB, r1)
            logits = qb @ k[c0:c1].T
            if c1 > r0:  # diagonal tile: mask j > i
                rows = np.arange(r0, r1)[:, None]
                cols = np.arange(c0, c1)[None, :]
                logits = np.where(cols <= rows, logits, -np.inf)
            m_new = np.maximum(m, logits.max(axis=1))
            corr = np.exp(m - m_new)
            p = np.exp(logits - m_new[:, None])
            s = s * corr + p.sum(axis=1)
            acc = acc * corr[:, None] + p @ v[c0:c1]
            m = m_new
        y[r0:r1] = acc / s[:, None]
    return y


def causal_probs(q, k):
    """The training forward's cached softmax rows (zeros above diag)."""
    n = q.shape[0]
    logits = q @ k.T
    mask = np.tril(np.ones((n, n), dtype=bool))
    logits = np.where(mask, logits, -np.inf)
    w = np.exp(logits - logits.max(axis=1, keepdims=True))
    return w / w.sum(axis=1, keepdims=True)


def rowstream_bwd(probs, q, k, v, dout):
    dv = probs.T @ dout
    dp = dout @ v.T
    dd = (probs * dp).sum(axis=1)
    ds = probs * (dp - dd[:, None])
    return ds @ k, ds.T @ q, dv


def blocked_bwd(probs, q, k, v, dout):
    n, _ = q.shape
    dq = np.zeros_like(q)
    dk = np.zeros_like(k)
    dv = np.zeros_like(v)
    for r0 in range(0, n, RB):
        r1 = min(r0 + RB, n)
        p = probs[r0:r1, :r1]  # causal prefix only: half the flops
        dp = dout[r0:r1] @ v[:r1].T
        dd = (p * dp).sum(axis=1)
        ds = p * (dp - dd[:, None])
        dq[r0:r1] = ds @ k[:r1]
        dk[:r1] += ds.T @ q[r0:r1]
        dv[:r1] += p.T @ dout[r0:r1]
    return dq, dk, dv


def rowstream_decode(h, v):
    w = np.exp(h - h.max())
    return (w @ v) / w.sum()


def blocked_decode(h, v):
    d = v.shape[1]
    m, s, acc = -np.inf, 0.0, np.zeros(d)
    for c0 in range(0, len(h), CB):
        tile = h[c0 : c0 + CB]
        m_new = max(m, tile.max())
        corr = np.exp(m - m_new)
        p = np.exp(tile - m_new)
        s = s * corr + p.sum()
        acc = acc * corr + p @ v[c0 : c0 + CB]
        m = m_new
    return acc / s


def median_time(f, iters=ITERS):
    f()  # warmup
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        f()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def fmt(t):
    return f"{t * 1e3:.2f}ms" if t >= 1e-3 else f"{t * 1e6:.0f}µs"


def main():
    rng = np.random.default_rng(10)
    print("# blocked_attn mirror: row-stream vs blocked (NumPy, not the Rust bench)")
    print(f"(d_h={D}, row-block {RB}, column tile {CB})\n")
    print("| lane | n | row-stream | blocked | blocked x |")
    print("|---|---|---|---|---|")
    for n in NS:
        q = 0.5 * rng.standard_normal((n, D))
        k = 0.5 * rng.standard_normal((n, D))
        v = rng.standard_normal((n, D))
        dout = rng.standard_normal((n, D))

        # Contract check before timing: the documented tolerance.
        tol = blocked_rtol(n) * max(np.abs(v).max(), 1.0)
        err = np.abs(blocked_fwd(q, k, v) - rowstream_fwd(q, k, v)).max()
        assert err <= tol, f"n={n}: blocked fwd drifted {err:.3e} > {tol:.3e}"

        t_rs = median_time(lambda: rowstream_fwd(q, k, v))
        t_bl = median_time(lambda: blocked_fwd(q, k, v))
        print(f"| fwd | {n} | {fmt(t_rs)} | {fmt(t_bl)} | {t_rs / t_bl:.2f}x |")

        probs = causal_probs(q, k)
        t_rs_b = median_time(lambda: rowstream_bwd(probs, q, k, v, dout))
        t_bl_b = median_time(lambda: blocked_bwd(probs, q, k, v, dout))
        print(f"| bwd | {n} | {fmt(t_rs_b)} | {fmt(t_bl_b)} | {t_rs_b / t_bl_b:.2f}x |")

        h = q[n - 1] @ k.T
        steps = 64
        t_rs_d = median_time(lambda: [rowstream_decode(h, v) for _ in range(steps)])
        t_bl_d = median_time(lambda: [blocked_decode(h, v) for _ in range(steps)])
        print(f"| decode | {n} | {fmt(t_rs_d)} | {fmt(t_bl_d)} | {t_rs_d / t_bl_d:.2f}x |")

    # Adversarial-scale survival (the satellite-1 regression, mirrored):
    # logits far past exp's overflow point must still give a convex
    # combination, on both families.
    n = 256
    q = 20.0 * rng.standard_normal((n, D))
    k = 20.0 * rng.standard_normal((n, D))
    v = np.ones((n, D))
    for name, f in [("row-stream", rowstream_fwd), ("blocked", blocked_fwd)]:
        y = f(q, k, v)
        assert np.isfinite(y).all(), f"{name}: non-finite at adversarial scale"
        assert np.abs(y - 1.0).max() <= blocked_rtol(n), name
    print("\nadversarial-scale check: both families finite and ~1.0 on V=ones "
          "at logit scale ~20 (raw exp would overflow) -- ok")


if __name__ == "__main__":
    main()
