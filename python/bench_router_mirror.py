"""NumPy mirror of ``benches/router.rs`` (PR 9, adaptive router).

The Rust bench is the source of truth, but some build images carry no
Rust toolchain; this mirror reproduces the *same strategies* with the
same asymptotics so the router's cost story stays measured anywhere
NumPy exists. Per (layer, head) causal prefill at length n, head dim
d:

* ``exact``   — masked softmax attention, O(n^2 * d)
                (BatchedBackend::Exact)
* ``conv(k)`` — k column probes + per-V-column FFT applies of the
                recovered basis, O(k*n*d*log n)
                (BatchedBackend::Strided(k) / Conv)
* ``lowrank`` — degree-g truncated-Taylor features (rank
                k_f = C(d+g, g)) + causal prefix-sum multiply,
                O(n * k_f * d)   (BatchedBackend::LowRank, Thm 6.5)
* ``routed``  — the mixed per-head table from ``benches/router.rs``
                (1 exact + 2 conv + 1 low-rank head): routing is a
                table lookup, so the routed cost must price like the
                mix of its resolved backends — that is the bench's
                claim, and the mirror's.

The accuracy table mirrors the documented ``LOWRANK_RTOL`` of
``rust/tests/router.rs``: entries uniform in [-0.4, 0.4), d = 4,
AS23 scale beta = d, measured normalized error max|Y - Y~| / ||V||_inf
against the analytic pins 0.08 (g = 1) and 0.01 (g = 2).

Run: ``python3 python/bench_router_mirror.py`` (prints markdown
tables; numbers land in EXPERIMENTS.md, clearly labelled as the
mirror, not the Rust bench).
"""

import itertools
import math
import time

import numpy as np

D = 8
K = 8  # conv route's basis size
NS = [256, 1024, 4096]
ITERS = 3


def exact_prefill(q, k, v):
    logits = q @ k.T
    w = np.tril(np.exp(logits - logits.max(axis=1, keepdims=True)))
    return (w @ v) / w.sum(axis=1, keepdims=True)


def conv_prefill(q, k, v, kb):
    """k column probes + FFT applies (the strided-recovery cost shape)."""
    n, d = q.shape
    onsets = np.linspace(0, n - 1, kb, dtype=int)
    # Probes: one exp(QK^T) column per onset (O(n*d) each).
    cols = np.exp(q @ k[onsets].T)  # (n, kb)
    # FFT apply: each basis vector convolved with each V column.
    m = 1 << (2 * n - 1).bit_length()
    fb = np.fft.rfft(cols, n=m, axis=0)  # (m', kb)
    fv = np.fft.rfft(v, n=m, axis=0)  # (m', d)
    y = np.zeros((n, d))
    for r in range(kb):
        y += np.fft.irfft(fb[:, r : r + 1] * fv, n=m, axis=0)[:n]
    norm = np.cumsum(cols.sum(axis=1))
    return y / norm[:, None]


def taylor_features(x, degree, scale):
    """Degree-g monomial features of x/sqrt(scale): rank C(d+g, g)."""
    n, d = x.shape
    xs = x / math.sqrt(scale)
    feats = [np.ones((n, 1))]
    for g in range(1, degree + 1):
        coef = 1.0 / math.sqrt(math.factorial(g))
        for combo in itertools.combinations_with_replacement(range(d), g):
            col = np.ones(n) * coef
            for j in combo:
                col = col * xs[:, j]
            feats.append(col[:, None])
    return np.concatenate(feats, axis=1)


def lowrank_prefill_loop(q, k, v, degree, scale):
    """Causal prefix-sum multiply over the polynomial features."""
    u1 = taylor_features(q, degree, scale)
    u2 = taylor_features(k, degree, scale)
    n, kf = u1.shape
    # Prefix sums: S_i = sum_{j<=i} u2_j v_j^T  (kf x d), s_i = sum u2_j.
    s_mat = np.cumsum(u2[:, :, None] * v[:, None, :], axis=0)  # (n, kf, d)
    s_vec = np.cumsum(u2, axis=0)  # (n, kf)
    num = np.einsum("ik,ikd->id", u1, s_mat)
    den = np.einsum("ik,ik->i", u1, s_vec)
    return num / den[:, None]


def median_time(f, iters=ITERS):
    f()  # warmup
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        f()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def fmt(t):
    return f"{t * 1e3:.2f}ms" if t >= 1e-3 else f"{t * 1e6:.0f}µs"


def main():
    rng = np.random.default_rng(0xBE)
    print("# PR 9 mirror — per-backend vs routed prefill (NumPy)")
    print()
    print("| n | exact | conv(k=8) | lowrank(g=2) | routed(mixed) |")
    print("|---|---|---|---|---|")
    for n in NS:
        q = rng.uniform(-0.4, 0.4, (n, D))
        k = rng.uniform(-0.4, 0.4, (n, D))
        v = rng.uniform(-0.4, 0.4, (n, D))
        t_exact = median_time(lambda: exact_prefill(q, k, v))
        t_conv = median_time(lambda: conv_prefill(q, k, v, K))
        t_low = median_time(lambda: lowrank_prefill_loop(q, k, v, 2, float(D)))
        # benches/router.rs table: heads 0..3 -> exact, strided, conv, lowrank.
        t_routed = median_time(
            lambda: (
                exact_prefill(q, k, v),
                conv_prefill(q, k, v, K),
                conv_prefill(q, k, v, K),
                lowrank_prefill_loop(q, k, v, 2, float(D)),
            )
        )
        print(
            f"| {n} | {fmt(t_exact)} | {fmt(t_conv)} | {fmt(t_low)} "
            f"| {fmt(t_routed)} |"
        )
    print()
    print("routed table: (0,0)->Exact  (0,1)->Strided(8)  (0,2)->Conv  "
          "(0,3)->LowRank(g=2)")
    print()

    print("## lowrank accuracy vs documented LOWRANK_RTOL "
          "(d=4, scale=4, entries U[-0.4,0.4))")
    print()
    print("| n | g | measured max|err|/‖V‖∞ | documented pin |")
    print("|---|---|---|---|")
    d, scale = 4, 4.0
    for n in [8, 32, 64, 256]:
        q = rng.uniform(-0.4, 0.4, (n, d))
        k = rng.uniform(-0.4, 0.4, (n, d))
        v = rng.uniform(-0.4, 0.4, (n, d))
        logits = q @ k.T / scale
        w = np.tril(np.exp(logits))
        oracle = (w @ v) / w.sum(axis=1, keepdims=True)
        for g, pin in [(1, 0.08), (2, 0.01)]:
            approx = lowrank_prefill_loop(q, k, v, g, scale)
            err = np.abs(approx - oracle).max() / np.abs(v).max()
            ok = "ok" if err <= pin else "EXCEEDS"
            print(f"| {n} | {g} | {err:.2e} ({ok}) | {pin} |")


if __name__ == "__main__":
    main()
