"""NumPy mirror of ``benches/decode_step.rs`` and ``benches/grad_batch.rs``.

The Rust benches are the source of truth, but some build images carry
no Rust toolchain; this mirror reproduces the *same strategies* with
the same asymptotics so scaling claims stay measured anywhere NumPy
exists. Costs mirrored per generated token, per (sequence, head), on
Toeplitz-structured logits (the conv-exact case):

* ``conv step``       — grow cached basis + banded weighted sum,
                        O(k*n + n*d)   (DecodeOp::Conv)
* ``exact row``       — logits row + softmax + weighted sum,
                        O(n*d)         (DecodeOp::Exact / KV cache)
* ``conv reprefill``  — k column probes + FFT apply of the basis,
                        O(k*n*d + k*n*log n*d)
* ``exact reprefill`` — full masked softmax attention, O(n^2*d)

Gradient mirror (``benches/grad_batch.rs`` strategies, per (layer,
head) Definition 5.1 backward at the point X — d applies for f·h plus
d*(d+1) applies for the tensor-trick columns):

* ``grad conv``  — every ``f·w`` through the k=1 conv basis via FFT,
                   O(d^2 * n log n)   (the engine's Gradient lane)
* ``grad dense`` — materialize f (n x n) once, dense matvecs,
                   O(n^2 * d^2)       (the pre-Theorem-C.17 cost)

LM attention-backward mirror (``benches/lm_backward.rs`` strategies,
one (layer, head) d(Q,K,V) backward given upstream ``dout`` — uses both
``f·w`` and the transposed ``f^T·w`` applies, the conv structure
surviving transposition as a reversed-window correlation):

* ``bwd conv``  — d applies for f·V plus d transposed applies for dV
                  plus d*(d+1) of each for dQ/dK through the
                  diag-sandwich identity, O(d^2 * n log n)
                  (the engine's AttnBackward lane, fast mode)
* ``bwd dense`` — materialize f (n x n), matrix-form softmax backward
                  with three n x n temporaries, O(n^2 * d)
                  (the pre-PR-4 ``Transformer::backward`` inner loop)

Run: ``python3 python/bench_decode_mirror.py`` (prints markdown
tables; numbers land in EXPERIMENTS.md, clearly labelled as the
mirror, not the Rust bench).
"""

import time

import numpy as np

D = 16
K = 8


def timeit(f, iters):
    f()  # warmup
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        f()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def fmt(seconds):
    if seconds < 1e-3:
        return f"{seconds * 1e6:.0f}us"
    if seconds < 1.0:
        return f"{seconds * 1e3:.2f}ms"
    return f"{seconds:.2f}s"


def bench(n, d=D, k=K):
    rng = np.random.default_rng(n)
    # Toeplitz pre-exp logits H[i, j] = g[i-j] (causal), grown to n+1.
    g = rng.normal(scale=0.5, size=n + 1)
    q = rng.normal(size=(n + 1, d))
    kk = rng.normal(size=(n + 1, d))
    v = rng.normal(size=(n + 1, d))
    b = np.exp(g[:n])  # cached post-exp basis (k=1, full window)
    new_row = g[n::-1]  # pre-exp row n: H[n, j] = g[n-j]

    def conv_step():
        # append_token + attend_last: O(k*n) basis work + O(n*d) sum.
        b1 = np.concatenate([b, [np.exp(new_row[0])]])
        d_new = np.exp(new_row).sum()
        w = b1[::-1]  # weight at column j is b1[n-j]
        return (w @ v) / d_new

    def exact_row():
        row = kk @ q[n]  # O(n*d) logits row
        wr = np.exp(row - row.max())
        return (wr @ v) / wr.sum()

    def conv_reprefill():
        # Strided recovery probes (k columns of Q·k_s)…
        for s in [j * (n + 1) // k for j in range(k)]:
            _ = q[s:] @ kk[s]
        # …then the FFT apply of the recovered basis per V column.
        bb = np.exp(g)
        fb = np.fft.rfft(bb, 2 * (n + 1))
        out = np.empty_like(v)
        for c in range(d):
            out[:, c] = np.fft.irfft(fb * np.fft.rfft(v[:, c], 2 * (n + 1)))[: n + 1]
        return out / np.cumsum(bb)[:, None]

    def exact_reprefill():
        h = q @ kk.T
        a = np.exp(h - h.max(axis=1, keepdims=True)) * np.tri(n + 1)
        return (a @ v) / a.sum(axis=1, keepdims=True)

    iters = 3 if n >= 4096 else 7
    return [timeit(f, iters) for f in (conv_step, exact_row, conv_reprefill, exact_reprefill)]


GRAD_D = 8


def bench_grad(n, d=GRAD_D):
    rng = np.random.default_rng(n + 1)
    # Toeplitz pre-exp logits H[i, j] = g[i-j] (causal): the k=1
    # conv-exact case, mirroring GradJob on a structured problem.
    g = rng.normal(scale=0.5, size=n)
    b = np.exp(g)              # post-exp basis (k=1, full window)
    dvec = np.cumsum(b)        # row sums of the lower-triangular conv
    h = rng.normal(size=(n, d))    # h(y) = A3·Y
    e = rng.normal(size=(n, d))    # target E
    a2 = rng.normal(size=(n, d))
    fb = np.fft.rfft(b, 2 * n)

    def f_apply(w):
        # One f·w: k-conv FFT apply + diagonal normalizer.
        return np.fft.irfft(fb * np.fft.rfft(w, 2 * n))[:n] / dvec

    def tensor_trick(apply_f):
        # Lemmas C.10–C.16 with a pluggable f·w (d + d*(d+1) applies).
        fh = np.stack([apply_f(h[:, i]) for i in range(d)], axis=1)
        c = fh - e
        r = np.einsum("ij,ij->i", fh, c)
        pa2 = np.empty((n, d))
        for col in range(d):
            w = a2[:, col]
            acc = np.zeros(n)
            for i in range(d):
                acc += c[:, i] * apply_f(h[:, i] * w)
            acc -= r * apply_f(w)
            pa2[:, col] = acc
        return pa2

    def grad_conv():
        return tensor_trick(f_apply)

    def grad_dense():
        # Materialize f once (part of the cost), then dense matvecs.
        idx = np.subtract.outer(np.arange(n), np.arange(n))
        f = np.where(idx >= 0, b[np.clip(idx, 0, n - 1)], 0.0) / dvec[:, None]
        return tensor_trick(lambda w: f @ w)

    assert np.allclose(grad_conv(), grad_dense(), atol=1e-8)
    iters = 2 if n >= 4096 else 5
    return [timeit(f, iters) for f in (grad_conv, grad_dense)]


def bench_lm_backward(n, d=GRAD_D):
    rng = np.random.default_rng(n + 2)
    # Toeplitz post-exp operator (the k=1 conv-exact softmax surrogate):
    # f = conv(b) lower-triangular, row-normalized.
    g = rng.normal(scale=0.5, size=n)
    b = np.exp(g)
    dvec = np.cumsum(b)
    q = rng.normal(size=(n, d))
    k = rng.normal(size=(n, d))
    v = rng.normal(size=(n, d))
    dout = rng.normal(size=(n, d))
    fb = np.fft.rfft(b, 2 * n)

    def f_apply(w):
        return np.fft.irfft(fb * np.fft.rfft(w, 2 * n))[:n] / dvec

    def ft_apply(w):
        # f^T·w = B^T·(w / dvec): a correlation = reversed convolution,
        # same FFT cost (mirrors KConvBasis::apply_transpose).
        s = (w / dvec)[::-1]
        return np.fft.irfft(fb * np.fft.rfft(s, 2 * n))[:n][::-1]

    def bwd_conv():
        y = np.stack([f_apply(v[:, c]) for c in range(d)], axis=1)
        r = np.einsum("ij,ij->i", dout, y)
        dv = np.stack([ft_apply(dout[:, c]) for c in range(d)], axis=1)
        dq = np.empty((n, d))
        dk = np.empty((n, d))
        for col in range(d):
            acc = np.zeros(n)
            for c in range(d):
                acc += dout[:, c] * f_apply(v[:, c] * k[:, col])
            dq[:, col] = acc - r * f_apply(k[:, col])
            acc = np.zeros(n)
            for c in range(d):
                acc += v[:, c] * ft_apply(dout[:, c] * q[:, col])
            dk[:, col] = acc - ft_apply(r * q[:, col])
        return dq, dk, dv

    def bwd_dense():
        # Materialize f once (part of the cost), then the matrix-form
        # backward with its n x n temporaries.
        idx = np.subtract.outer(np.arange(n), np.arange(n))
        f = np.where(idx >= 0, b[np.clip(idx, 0, n - 1)], 0.0) / dvec[:, None]
        y = f @ v
        r = np.einsum("ij,ij->i", dout, y)
        dv = f.T @ dout
        dp = dout @ v.T
        ds = f * dp - r[:, None] * f
        return ds @ k, ds.T @ q, dv

    for a, bb in zip(bwd_conv(), bwd_dense()):
        assert np.allclose(a, bb, atol=1e-8)
    iters = 2 if n >= 4096 else 5
    return [timeit(lambda: bwd_conv()[0], iters), timeit(lambda: bwd_dense()[0], iters)]


def bench_lm_step(n, d=GRAD_D):
    """Mirror of ``benches/lm_step.rs`` at the head level: one training
    step's attention work — forward apply THEN the d(Q,K,V) backward
    *reusing the same operator* (the step-scoped basis handoff) — conv
    vs dense, k=1 Toeplitz (the conv-exact case). The conv step builds
    the basis once (recovery surrogate: the FFT spectrum) and both
    halves consume it; the dense step materializes f once and both
    halves consume that — the fair mirror of "recover/materialize once
    per step"."""
    rng = np.random.default_rng(n + 3)
    g = rng.normal(scale=0.5, size=n)
    b = np.exp(g)
    dvec = np.cumsum(b)
    q = rng.normal(size=(n, d))
    k = rng.normal(size=(n, d))
    v = rng.normal(size=(n, d))
    dout = rng.normal(size=(n, d))

    def conv_step():
        fb = np.fft.rfft(b, 2 * n)  # the once-per-step recovery product

        def f_apply(w):
            return np.fft.irfft(fb * np.fft.rfft(w, 2 * n))[:n] / dvec

        def ft_apply(w):
            s = (w / dvec)[::-1]
            return np.fft.irfft(fb * np.fft.rfft(s, 2 * n))[:n][::-1]

        # Forward: Y = f·V (what the training forward returns).
        y = np.stack([f_apply(v[:, c]) for c in range(d)], axis=1)
        # Backward: the diag-sandwich chains over the SAME operator.
        r = np.einsum("ij,ij->i", dout, y)
        dv = np.stack([ft_apply(dout[:, c]) for c in range(d)], axis=1)
        dq = np.empty((n, d))
        dk = np.empty((n, d))
        for col in range(d):
            acc = np.zeros(n)
            for c in range(d):
                acc += dout[:, c] * f_apply(v[:, c] * k[:, col])
            dq[:, col] = acc - r * f_apply(k[:, col])
            acc = np.zeros(n)
            for c in range(d):
                acc += v[:, c] * ft_apply(dout[:, c] * q[:, col])
            dk[:, col] = acc - ft_apply(r * q[:, col])
        return y, dq, dk, dv

    def dense_step():
        idx = np.subtract.outer(np.arange(n), np.arange(n))
        f = np.where(idx >= 0, b[np.clip(idx, 0, n - 1)], 0.0) / dvec[:, None]
        y = f @ v
        r = np.einsum("ij,ij->i", dout, y)
        dv = f.T @ dout
        dp = dout @ v.T
        ds = f * dp - r[:, None] * f
        return y, ds @ k, ds.T @ q, dv

    for a, bb in zip(conv_step(), dense_step()):
        assert np.allclose(a, bb, atol=1e-8)
    iters = 2 if n >= 4096 else 5
    return [timeit(lambda: conv_step()[1], iters), timeit(lambda: dense_step()[1], iters)]


def main():
    print(f"# decode step vs re-prefill — NumPy mirror (d={D}, k={K})")
    header = ["n", "conv step", "exact row", "conv reprefill", "exact reprefill",
              "step/conv-rp", "step/exact-rp"]
    print("| " + " | ".join(header) + " |")
    print("|" + "---|" * len(header))
    for n in (256, 1024, 4096):
        ts = bench(n)
        row = [str(n)] + [fmt(t) for t in ts] + [
            f"{ts[2] / ts[0]:.0f}x",
            f"{ts[3] / ts[0]:.0f}x",
        ]
        print("| " + " | ".join(row) + " |")

    print()
    print(f"# fast gradient vs dense-f gradient — NumPy mirror (d={GRAD_D}, k=1)")
    header = ["n", "grad conv", "grad dense", "dense/conv"]
    print("| " + " | ".join(header) + " |")
    print("|" + "---|" * len(header))
    for n in (256, 1024, 4096):
        tc, td = bench_grad(n)
        print(f"| {n} | {fmt(tc)} | {fmt(td)} | {td / tc:.0f}x |")

    print()
    print(f"# LM attention backward conv vs dense — NumPy mirror (d={GRAD_D}, k=1)")
    header = ["n", "bwd conv", "bwd dense", "dense/conv"]
    print("| " + " | ".join(header) + " |")
    print("|" + "---|" * len(header))
    for n in (256, 1024, 4096):
        tc, td = bench_lm_backward(n)
        print(f"| {n} | {fmt(tc)} | {fmt(td)} | {td / tc:.1f}x |")

    print()
    print(f"# full training step (fwd+bwd, shared basis) conv vs dense — "
          f"NumPy mirror (d={GRAD_D}, k=1)")
    header = ["n", "step conv", "step dense", "dense/conv"]
    print("| " + " | ".join(header) + " |")
    print("|" + "---|" * len(header))
    for n in (256, 1024, 4096):
        tc, td = bench_lm_step(n)
        print(f"| {n} | {fmt(tc)} | {fmt(td)} | {td / tc:.1f}x |")


if __name__ == "__main__":
    main()
