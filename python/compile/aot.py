"""AOT lowering: jax → HLO **text** → artifacts/ for the Rust runtime.

HLO text (NOT `lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()`)
is the interchange format: jax ≥ 0.5 emits protos with 64-bit instruction
ids that xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and gen_hlo.py there.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_conv_attention(n: int, d: int, k: int, blk: int) -> tuple[str, dict]:
    var = model.default_variant(n=n, d=d, k=k)
    ms = var["ms"]

    def fn(bases, v):
        return model.conv_attention(bases, v, ms=ms, blk=blk)

    bases_spec = jax.ShapeDtypeStruct((k, n), jnp.float32)
    v_spec = jax.ShapeDtypeStruct((n, d), jnp.float32)
    lowered = jax.jit(fn).lower(bases_spec, v_spec)
    meta = {
        "kind": "conv_attention",
        "n": n,
        "d": d,
        "k": k,
        "ms": list(ms),
        "blk": blk,
        "inputs": [["bases", [k, n]], ["v", [n, d]]],
        "outputs": [["y", [n, d]]],
    }
    return to_hlo_text(lowered), meta


def lower_lowrank_causal(n: int, d: int, rank: int, blk: int) -> tuple[str, dict]:
    def fn(u1, u2, v):
        return model.lowrank_causal_attention(u1, u2, v, blk=blk)

    u_spec = jax.ShapeDtypeStruct((n, rank), jnp.float32)
    v_spec = jax.ShapeDtypeStruct((n, d), jnp.float32)
    lowered = jax.jit(fn).lower(u_spec, u_spec, v_spec)
    meta = {
        "kind": "lowrank_causal",
        "n": n,
        "d": d,
        "rank": rank,
        "blk": blk,
        "inputs": [["u1", [n, rank]], ["u2", [n, rank]], ["v", [n, d]]],
        "outputs": [["y", [n, d]]],
    }
    return to_hlo_text(lowered), meta


def lower_exact_attention(n: int, d: int) -> tuple[str, dict]:
    spec = jax.ShapeDtypeStruct((n, d), jnp.float32)
    lowered = jax.jit(model.exact_attention).lower(spec, spec, spec)
    meta = {
        "kind": "exact_attention",
        "n": n,
        "d": d,
        "inputs": [["q", [n, d]], ["k", [n, d]], ["v", [n, d]]],
        "outputs": [["y", [n, d]]],
    }
    return to_hlo_text(lowered), meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--blk", type=int, default=128)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    built = []
    for name, (text, meta) in {
        "conv_attention": lower_conv_attention(args.n, args.d, args.k, args.blk),
        "exact_attention": lower_exact_attention(args.n, args.d),
        "lowrank_causal": lower_lowrank_causal(args.n, args.d, 16, args.blk),
    }.items():
        hlo_path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(hlo_path, "w") as f:
            f.write(text)
        with open(os.path.join(args.out_dir, f"{name}.meta.json"), "w") as f:
            json.dump(meta, f, indent=2)
        built.append((hlo_path, len(text)))
    for path, size in built:
        print(f"wrote {path} ({size} chars)")


if __name__ == "__main__":
    main()
