"""L2: the jax compute graphs that get AOT-lowered for the Rust runtime.

Two build-time artifacts (one executable per model variant — shapes and
window sizes are baked at lowering time):

* ``conv_attention``  — Algorithm 1's apply: given the exp-transformed
  basis bank (k, n) and V (n, d), return Ỹ = D̃⁻¹·(Σ conv(b̃_r, m_r))·V.
  The hot-spot runs through the L1 Pallas kernel
  (`kernels.conv_attention`), so the kernel lowers into the same HLO.
* ``exact_attention`` — the quadratic baseline (Definition 3.3), used by
  the Rust integration tests to cross-check numerics between the native
  path and the PJRT path.

Python never runs at serving time: `make artifacts` lowers these once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.conv_attention import conv_attention_pallas
from .kernels.lowrank_causal import causal_lowrank_attention_pallas
from .kernels import ref


def conv_attention(bases: jnp.ndarray, v: jnp.ndarray, *, ms, blk: int = 128):
    """Normalized k-conv attention through the Pallas kernel.

    `ms` is static (baked into the artifact); returns a 1-tuple so the
    lowered computation is a tuple root (the xla crate unwraps it with
    `to_tuple1`).
    """
    return (conv_attention_pallas(bases, ms, v, blk=blk),)


def conv_attention_ref_graph(bases: jnp.ndarray, v: jnp.ndarray, *, ms):
    """Same computation through the dense jnp oracle (shape-check /
    ablation artifact)."""
    return (ref.conv_attention_ref(bases, ms, v),)


def exact_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray):
    """Exact causal attention baseline (Definition 3.3)."""
    return (ref.exact_attention_ref(q, k, v),)


def lowrank_causal_attention(u1: jnp.ndarray, u2: jnp.ndarray, v: jnp.ndarray, *, blk: int = 128):
    """Theorem 6.5 causal low-rank attention through the Algorithm-4
    prefix-scan kernel (second L1 kernel)."""
    return (causal_lowrank_attention_pallas(u1, u2, v, blk=blk),)


def default_variant(n: int = 256, d: int = 32, k: int = 4):
    """The artifact variant built by default: geometric window schedule
    m = (n, n/2, n/4, …) — the shape the serving layer requests."""
    ms = tuple(max(1, n >> r) for r in range(k))
    return {"n": n, "d": d, "k": k, "ms": ms}
