"""L1 Pallas kernel #2: Algorithm 4 (causal masked low-rank multiply)
as a TPU prefix scan.

Computes `Y = (M_causal ∘ U₁U₂ᵀ)·V` without materializing the n×n
product, via the Lemma D.5 identity `Y_j = ⟨(U₁)_j, c_j⟩` with the
running prefix state `c_j = Σ_{l≤j} (U₂)_l ⊗ V_l ∈ R^{k×d}`.

TPU mapping: the grid walks row blocks **sequentially** (TPU grids are
sequential on a core, which is exactly what a scan needs); the carry
`c` lives in a revisited output block (constant index_map), so each
step sees the previous step's state. Within a block the causal prefix
is a `cumsum` over the BLK axis of the rank-k outer products, followed
by one einsum against U₁ — all MXU/VPU-friendly dense ops.

Cost: O(n·k·d) flops, O(nk + nd) HBM traffic — the Theorem 6.5 causal
row. interpret=True for the CPU image, as with the conv kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(u1_ref, u2_ref, v_ref, y_ref, carry_ref, *, blk):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    u1 = u1_ref[...]  # (blk, k)
    u2 = u2_ref[...]  # (blk, k)
    v = v_ref[...]  # (blk, d)
    c_in = carry_ref[...]  # (k, d) carry from previous blocks

    # Rank-k outer products per row: (blk, k, d), then inclusive prefix.
    outers = u2[:, :, None] * v[:, None, :]
    prefix = jnp.cumsum(outers, axis=0)  # c within the block
    # c_j for row p = c_in + prefix[p]  → y[p] = Σ_k u1[p,k]·c_j[k,:]
    y = jnp.einsum("pk,pkd->pd", u1, prefix) + u1 @ c_in
    y_ref[...] = y
    carry_ref[...] = c_in + prefix[blk - 1]


def causal_lowrank_pallas(u1: jnp.ndarray, u2: jnp.ndarray, v: jnp.ndarray, blk: int = 128):
    """`(M_causal ∘ U₁U₂ᵀ)·V` via the sequential-grid prefix scan."""
    n, k = u1.shape
    d = v.shape[1]
    assert u2.shape == (n, k) and v.shape[0] == n
    blk = min(blk, n)
    assert n % blk == 0, f"blk {blk} must divide n {n}"
    kernel = functools.partial(_kernel, blk=blk)
    y, _carry = pl.pallas_call(
        kernel,
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk, k), lambda bi: (bi, 0)),
            pl.BlockSpec((blk, k), lambda bi: (bi, 0)),
            pl.BlockSpec((blk, d), lambda bi: (bi, 0)),
        ],
        out_specs=(
            pl.BlockSpec((blk, d), lambda bi: (bi, 0)),
            # The carry: one (k, d) block revisited by every grid step.
            pl.BlockSpec((k, d), lambda bi: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n, d), v.dtype),
            jax.ShapeDtypeStruct((k, d), v.dtype),
        ),
        interpret=True,
    )(u1, u2, v)
    return y


def causal_lowrank_attention_pallas(
    u1: jnp.ndarray, u2: jnp.ndarray, v: jnp.ndarray, blk: int = 128
):
    """Normalized Theorem 6.5 attention: `D̃⁻¹ (M∘U₁U₂ᵀ) V` (Lemma D.3:
    one extra multiply with 1ₙ gives the normalizer)."""
    ones = jnp.ones((v.shape[0], 1), dtype=v.dtype)
    num = causal_lowrank_pallas(u1, u2, v, blk=blk)
    den = causal_lowrank_pallas(u1, u2, ones, blk=blk)
    return num / den


def causal_lowrank_ref(u1: jnp.ndarray, u2: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Dense oracle."""
    n = u1.shape[0]
    a = jnp.where(jnp.tril(jnp.ones((n, n), dtype=bool)), u1 @ u2.T, 0.0)
    return a @ v
