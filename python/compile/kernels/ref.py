"""Pure-jnp oracle for the k-conv attention apply.

This is the CORE correctness signal for the L1 Pallas kernel: a dense,
obviously-correct construction of

    A = Σ_{r<k} conv(b_r, m_r)          (Definitions 3.5 / 3.9)
    Y = diag(A·1)^{-1} · A · V          (Algorithm 1 lines 3–4)

The dense build is O(n²) and only exists for testing; the kernel and the
Rust hot path never materialize A.
"""

from __future__ import annotations

import jax.numpy as jnp


def conv_matrix_dense(b: jnp.ndarray, m: int) -> jnp.ndarray:
    """Dense sub-convolution matrix conv(b, m) ∈ R^{n×n}.

    Entry (i, j) is b[i−j] when j ≥ n−m and i ≥ j, else 0.
    """
    n = b.shape[0]
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    offs = i - j
    vals = jnp.take(b, jnp.clip(offs, 0, n - 1), axis=0)
    mask = (offs >= 0) & (j >= n - m)
    return jnp.where(mask, vals, 0.0)


def kconv_dense(bases: jnp.ndarray, ms) -> jnp.ndarray:
    """Dense Σ_r conv(bases[r], ms[r]). `bases` is (k, n); `ms` static."""
    k, n = bases.shape
    acc = jnp.zeros((n, n), dtype=bases.dtype)
    for r in range(k):
        acc = acc + conv_matrix_dense(bases[r], int(ms[r]))
    return acc


def conv_attention_ref(bases: jnp.ndarray, ms, v: jnp.ndarray) -> jnp.ndarray:
    """Reference Ỹ = D̃⁻¹ (Σ_r conv(b̃_r, m_r)) V."""
    a = kconv_dense(bases, ms)
    d = a.sum(axis=1, keepdims=True)
    return (a @ v) / d


def conv_apply_ref(bases: jnp.ndarray, ms, v: jnp.ndarray):
    """Unnormalized numerator and row sums (what the kernel emits)."""
    a = kconv_dense(bases, ms)
    return a @ v, a.sum(axis=1)


def exact_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Exact causal softmax attention (Definition 3.3) — the baseline
    the second AOT artifact lowers."""
    n = q.shape[0]
    logits = q @ k.T
    mask = jnp.tril(jnp.ones((n, n), dtype=bool))
    a = jnp.where(mask, jnp.exp(logits), 0.0)
    d = a.sum(axis=1, keepdims=True)
    return (a @ v) / d
