"""L1 Pallas kernel: banded k-conv attention apply.

TPU rethink of the paper's FFT hot-spot (DESIGN.md §Hardware-Adaptation):
an FFT butterfly is hostile to the MXU systolic array, so the kernel
exploits the *same* structure the FFT exploits — Toeplitz redundancy —
in MXU-friendly form. The n×n operand `Σ_r conv(b̃_r, m_r)` is never
read from HBM; each BLK×BLK tile is **synthesized in VMEM from the
length-n basis vectors** (a gather along the diagonal offset) and
immediately contracted against the matching BLK×d tile of V:

    HBM traffic:  O(k·n + n·d)   (the paper's Appendix-A memory claim)
    VMEM working set per step: BLK² + BLK·d + k·n floats
    MXU work: one (BLK×BLK)·(BLK×d) matmul per causal tile

The grid is (row-blocks, col-blocks); the causal band makes the column
loop triangular (`pl.when(bj <= bi)`). Outputs: the unnormalized
numerator O = A·V and the row sums s = A·1; the final division happens
in the calling jax function (L2) so the kernel stays a pure contraction.

interpret=True everywhere: the CPU image cannot run Mosaic custom-calls;
real-TPU efficiency is *estimated* in EXPERIMENTS.md §Perf from the
block shapes above.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(b_ref, v_ref, o_ref, s_ref, *, ms, n, blk):
    """One (bi, bj) grid step: synthesize tile, contract, accumulate."""
    bi = pl.program_id(0)
    bj = pl.program_id(1)

    # Zero the accumulators on the first column-block visit.
    @pl.when(bj == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        s_ref[...] = jnp.zeros_like(s_ref)

    @pl.when(bj <= bi)
    def _compute():
        rows = bi * blk + jax.lax.iota(jnp.int32, blk)
        cols = bj * blk + jax.lax.iota(jnp.int32, blk)
        offs = rows[:, None] - cols[None, :]  # (blk, blk) diagonal offset
        causal = offs >= 0
        offs_c = jnp.clip(offs, 0, n - 1)
        tile = jnp.zeros((blk, blk), dtype=o_ref.dtype)
        bases = b_ref[...]  # (k, n) resident in VMEM
        for r, m in enumerate(ms):  # k is static — unrolled
            covered = cols[None, :] >= (n - int(m))
            vals = jnp.take(bases[r], offs_c, axis=0)
            tile = tile + jnp.where(causal & covered, vals, 0.0)
        v_tile = v_ref[...]  # (blk, d)
        o_ref[...] += jnp.dot(tile, v_tile, preferred_element_type=o_ref.dtype)
        s_ref[...] += tile.sum(axis=1, keepdims=True)


def conv_apply_pallas(bases: jnp.ndarray, ms, v: jnp.ndarray, blk: int = 128):
    """(A·V, A·1) for A = Σ_r conv(bases[r], ms[r]) via the banded kernel.

    bases: (k, n) float32; ms: static tuple of ints (n ≥ m_1 > … ≥ 1);
    v: (n, d). blk must divide n.
    """
    k, n = bases.shape
    d = v.shape[1]
    assert v.shape[0] == n
    blk = min(blk, n)
    assert n % blk == 0, f"blk {blk} must divide n {n}"
    ms = tuple(int(m) for m in ms)
    assert len(ms) == k
    grid = (n // blk, n // blk)

    kernel = functools.partial(_kernel, ms=ms, n=n, blk=blk)
    out_shapes = (
        jax.ShapeDtypeStruct((n, d), v.dtype),
        jax.ShapeDtypeStruct((n, 1), v.dtype),
    )
    o, s = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Whole basis bank resident (k·n floats — the paper's O(kn)).
            pl.BlockSpec((k, n), lambda bi, bj: (0, 0)),
            # V streamed one column-block at a time.
            pl.BlockSpec((blk, d), lambda bi, bj: (bj, 0)),
        ],
        out_specs=(
            # Output row-block revisited across the bj reduction.
            pl.BlockSpec((blk, d), lambda bi, bj: (bi, 0)),
            pl.BlockSpec((blk, 1), lambda bi, bj: (bi, 0)),
        ),
        out_shape=out_shapes,
        interpret=True,  # CPU image: Mosaic custom-calls cannot run here
    )(bases, v)
    return o, s[:, 0]


def conv_attention_pallas(bases: jnp.ndarray, ms, v: jnp.ndarray, blk: int = 128) -> jnp.ndarray:
    """Normalized conv attention Ỹ = D̃⁻¹·A·V (Algorithm 1 lines 3–5)."""
    o, s = conv_apply_pallas(bases, ms, v, blk=blk)
    return o / s[:, None]


def vmem_footprint_floats(k: int, n: int, d: int, blk: int) -> int:
    """Estimated VMEM working set of one grid step, in f32 words:
    basis bank + V tile + synthesized tile + output tiles.

    Used by EXPERIMENTS.md §Perf to pick blk per (n, d, k) and to
    estimate MXU utilization headroom on real hardware.
    """
    return k * n + blk * d + blk * blk + blk * d + blk


def mxu_utilization_estimate(n: int, blk: int) -> float:
    """Fraction of issued MXU tiles that carry useful (causal) work:
    lower-triangular block coverage of the band, ≈ (nb+1)/(2·nb) for
    nb = n/blk row blocks — the tile-synthesis overhead is amortized by
    the BLK×BLK×d contraction when d ≳ k."""
    nb = n // blk
    useful = nb * (nb + 1) / 2
    issued = nb * nb
    return useful / issued
