"""NumPy mirror of the speculative-decoding serving sweep (PR 7).

The Rust loadgen (``rust/src/bin/loadgen.rs``) is the source of truth,
but some build images carry no Rust toolchain; this mirror reproduces
the same serving shape as ``bench_net_mirror.py`` — TCP front-end,
newline-delimited flat-JSON framing, a scheduler thread, per-step
token streaming — and adds the PR 7 round structure on top:

* **draft**: γ_eff cheap decode steps per round through the k=1 conv
  stand-in (cached-basis banded weighted sum, ``O(k*n + n*d)`` per
  (layer, head)), plus one more append so the verifier sees every
  draft's KV row;
* **verify**: one exact pass over the γ_eff+1 trailing positions
  (softmax-weighted sums over the true, non-Toeplitz scores —
  ``O((γ+1)*n*d)`` per head), accept the longest draft prefix whose
  argmax matches, emit the bonus token, and roll the session back by
  pure truncation.

The drafter diverges from the verifier exactly the way the Rust conv
drafter does: the conv stand-in sees only the Toeplitz part of the
scores, the verifier sees scores plus the per-position perturbation
the conv basis cannot represent — so the acceptance rate is a real
measurement of "how often does a k=1 conv argmax match exact", not a
dialed-in constant. γ = 0 cells run the plain PR 6 decode loop.

Run: ``python3 python/bench_spec_mirror.py [--smoke] [--out PATH]``
(default out: ``BENCH_PR7.json``, schema ``bench_pr7/v1`` with
``"source": "numpy-mirror"`` so readers know which harness produced
the numbers).
"""

import json
import socket
import socketserver
import sys
import threading
import time
from collections import deque

import numpy as np

D_MODEL = 32
N_LAYERS = 2
N_HEADS = 2
D_HEAD = D_MODEL // N_HEADS
VOCAB = 256
MAX_QUEUE = 256
# Scale of the non-Toeplitz score component the k=1 conv drafter
# cannot see — the knob that makes acceptance < 1 without rigging it.
EPS_SCALE = 0.05


class Session:
    """One in-flight generation: per-(layer, head) cached conv basis
    plus the exact (perturbed) scores the verifier uses."""

    def __init__(self, req, wfile, lock):
        self.req = req
        self.wfile = wfile
        self.wlock = lock
        self.generated = []
        rng = np.random.default_rng(req["id"] + 1)
        self.rng = rng
        n = len(req["prompt"])
        self.n = n
        self.heads = []
        for _ in range(N_LAYERS * N_HEADS):
            g = rng.normal(scale=0.5, size=n)
            eps = rng.normal(scale=EPS_SCALE, size=n)
            self.heads.append(
                {"g": g, "eps": eps, "v": rng.normal(size=(n, D_HEAD))}
            )
        # Fixed token projection: argmax(W @ attention_row) is the
        # "logits" stand-in, shared by drafter and verifier.
        self.w_tok = rng.normal(size=(VOCAB, D_HEAD))

    def prefill(self):
        for h in self.heads:
            n = self.n
            fb = np.fft.rfft(np.exp(h["g"]), 2 * n)
            for c in range(D_HEAD):
                np.fft.irfft(fb * np.fft.rfft(h["v"][:, c], 2 * n))[:n]
        return self._exact_token(self.n - 1)

    def _append_row(self):
        """Grow every head by one position (draft-priced, conv path)."""
        for h in self.heads:
            h["g"] = np.append(h["g"], self.rng.normal(scale=0.5))
            h["eps"] = np.append(h["eps"], self.rng.normal(scale=EPS_SCALE))
            h["v"] = np.vstack([h["v"], self.rng.normal(size=(1, D_HEAD))])
        self.n += 1

    def _cheap_row(self, head):
        # k=1 conv stand-in: Toeplitz-only weights, O(k*n + n*d).
        b = np.exp(head["g"])
        w = b[::-1]
        return (w @ head["v"]) / b.sum()

    def _exact_row(self, head, p):
        # Exact verify row at position p: true (perturbed) scores.
        w = np.exp(head["g"][: p + 1] + head["eps"][: p + 1])[::-1]
        return (w @ head["v"][: p + 1]) / w.sum()

    def _cheap_token(self):
        rows = [self._cheap_row(h) for h in self.heads]
        return int(np.argmax(self.w_tok @ rows[0])), rows

    def _exact_token(self, p):
        rows = [self._exact_row(h, p) for h in self.heads]
        return int(np.argmax(self.w_tok @ rows[0]))

    def truncate(self, n):
        for h in self.heads:
            h["g"] = h["g"][:n]
            h["eps"] = h["eps"][:n]
            h["v"] = h["v"][:n]
        self.n = n

    def decode_plain(self):
        """γ = 0: one cheap append + cheap argmax (the PR 6 loop)."""
        self._append_row()
        tok, _ = self._cheap_token()
        self.generated.append(tok)
        return [tok]

    def decode_speculative(self, gamma):
        """One draft-γ/verify/rollback round; returns emitted tokens."""
        remaining = self.req["max_new_tokens"] - len(self.generated)
        g_eff = min(gamma, remaining - 1)
        if g_eff == 0:
            return self.decode_plain(), 0, 0
        base = self.n - 1
        drafts = []
        for _ in range(g_eff):
            self._append_row()
            tok, _ = self._cheap_token()
            drafts.append(tok)
        self._append_row()  # last draft's KV row, logits discarded
        accepted = 0
        while accepted < g_eff and self._exact_token(base + accepted) == drafts[accepted]:
            accepted += 1
        bonus = self._exact_token(base + accepted)
        self.truncate(base + 1 + accepted)
        emitted = drafts[:accepted] + [bonus]
        self.generated.extend(emitted)
        return emitted, g_eff, accepted


def write_line(wfile, wlock, obj):
    try:
        with wlock:
            wfile.write((json.dumps(obj, separators=(",", ":")) + "\n").encode())
            wfile.flush()
    except (OSError, ValueError):
        pass  # dead/closed client: it just stops receiving


class Scheduler:
    """Generation scheduler with a speculative round per iteration."""

    def __init__(self, gamma):
        self.gamma = gamma
        self.cv = threading.Condition()
        self.waiting = deque()
        self.shutting = False
        self.shed = 0
        self.drafted = 0
        self.accepted = 0
        self.thread = threading.Thread(target=self.run, daemon=True)
        self.thread.start()

    def submit(self, req, wfile, wlock):
        with self.cv:
            if self.shutting or len(self.waiting) >= MAX_QUEUE:
                self.shed += 1
                write_line(wfile, wlock, {"ev": "busy", "id": req["id"]})
                return
            self.waiting.append((req, wfile, wlock))
            self.cv.notify_all()

    def shutdown(self):
        with self.cv:
            self.shutting = True
            self.cv.notify_all()
        self.thread.join()

    def run(self):
        sessions = []
        while True:
            if not sessions:
                with self.cv:
                    while not self.waiting and not self.shutting:
                        self.cv.wait()
                    if self.shutting and not self.waiting:
                        return
            with self.cv:
                arrivals = list(self.waiting)
                self.waiting.clear()
            for req, wfile, wlock in arrivals:
                s = Session(req, wfile, wlock)
                tok = s.prefill()  # first token rides the prefill, exact
                s.generated.append(tok)
                write_line(wfile, wlock, {"ev": "token", "id": req["id"], "index": 0, "token": tok})
                sessions.append(s)
            retired = []
            for s in sessions:
                if self.gamma == 0:
                    emitted = s.decode_plain()
                else:
                    emitted, drafted, accepted = s.decode_speculative(self.gamma)
                    self.drafted += drafted
                    self.accepted += accepted
                start = len(s.generated) - len(emitted)
                for off, tok in enumerate(emitted):
                    write_line(
                        s.wfile,
                        s.wlock,
                        {"ev": "token", "id": s.req["id"], "index": start + off, "token": tok},
                    )
                if len(s.generated) >= s.req["max_new_tokens"]:
                    retired.append(s)
            for s in retired:
                sessions.remove(s)
                write_line(
                    s.wfile,
                    s.wlock,
                    {"ev": "done", "id": s.req["id"],
                     "prompt_len": len(s.req["prompt"]),
                     "decode_steps": len(s.generated),
                     "tokens": s.generated},
                )


class Handler(socketserver.StreamRequestHandler):
    disable_nagle_algorithm = True

    def handle(self):
        wlock = threading.Lock()
        for raw in self.rfile:
            line = raw.decode().strip()
            if not line:
                continue
            req = json.loads(line)
            if req.get("op") == "generate":
                self.server.scheduler.submit(req, self.wfile, wlock)
            else:
                write_line(self.wfile, wlock, {"ev": "error", "msg": "unknown op"})


def client_loop(addr, conn_id, prompt_len, decode_len, iters, out):
    sock = socket.create_connection(addr)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    rfile = sock.makefile("rb")
    prompt = [((conn_id * 131 + j * 17) % 255) + 1 for j in range(prompt_len)]
    lats, tokens, shed = [], 0, 0
    for i in range(iters):
        t0 = time.perf_counter()
        sock.sendall(
            (
                json.dumps(
                    {"op": "generate", "id": i, "prompt": prompt, "max_new_tokens": decode_len},
                    separators=(",", ":"),
                )
                + "\n"
            ).encode()
        )
        ttft = None
        for raw in rfile:
            ev = json.loads(raw)
            if ev["ev"] == "token":
                tokens += 1
                if ttft is None:
                    ttft = (time.perf_counter() - t0) * 1e6
            elif ev["ev"] == "done":
                lats.append((ttft, (time.perf_counter() - t0) * 1e6))
                break
            elif ev["ev"] == "busy":
                shed += 1
                break
    sock.close()
    out.append((lats, tokens, shed))


def pct(xs, q):
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(len(xs) - 1, round(q * (len(xs) - 1)))]


def run_cell(batch, prompt_len, decode_len, gamma, iters):
    server = socketserver.ThreadingTCPServer(("127.0.0.1", 0), Handler)
    server.daemon_threads = True
    server.scheduler = Scheduler(gamma)
    threading.Thread(target=server.serve_forever, daemon=True).start()
    addr = server.server_address

    t0 = time.perf_counter()
    out = []
    threads = [
        threading.Thread(target=client_loop, args=(addr, c, prompt_len, decode_len, iters, out))
        for c in range(batch)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0

    sched = server.scheduler
    sched.shutdown()
    server.shutdown()
    server.server_close()

    lats = [l for ls, _, _ in out for l in ls]
    tokens = sum(t for _, t, _ in out)
    shed = sum(s for _, _, s in out)
    accept_rate = 0.0 if sched.drafted == 0 else sched.accepted / sched.drafted
    return {
        "batch": batch,
        "prompt_len": prompt_len,
        "decode_len": decode_len,
        "gamma": gamma,
        "requests": len(lats),
        "tokens": tokens,
        "wall_s": round(wall, 6),
        "tokens_per_s": round(tokens / wall, 3),
        "accept_rate": round(accept_rate, 4),
        "ttft_p50_us": round(pct([l[0] for l in lats], 0.5), 1),
        "e2e_p50_us": round(pct([l[1] for l in lats], 0.5), 1),
        "e2e_p95_us": round(pct([l[1] for l in lats], 0.95), 1),
        "shed": shed,
    }


def main():
    smoke = "--smoke" in sys.argv
    out_path = "BENCH_PR7.json"
    if "--out" in sys.argv:
        out_path = sys.argv[sys.argv.index("--out") + 1]
    if smoke:
        batches, prompts, decodes, gammas, iters = [1, 2], [8, 16], [4], [0, 2], 2
    else:
        batches, prompts, decodes, gammas, iters = [1, 4, 8], [16, 64, 256], [8, 32], [0, 4], 3

    cells = []
    print("# Speculative serving sweep — NumPy mirror (k=1 conv draft, exact verify)")
    print("| batch | prompt | decode | γ | req | tok/s | accept | ttft p50 µs | e2e p50 µs | e2e p95 µs | shed |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for b in batches:
        for p in prompts:
            for d in decodes:
                for g in gammas:
                    c = run_cell(b, p, d, g, iters)
                    cells.append(c)
                    print(
                        f"| {b} | {p} | {d} | {g} | {c['requests']} | {c['tokens_per_s']:.1f} "
                        f"| {c['accept_rate']:.2f} | {c['ttft_p50_us']:.0f} "
                        f"| {c['e2e_p50_us']:.0f} | {c['e2e_p95_us']:.0f} | {c['shed']} |"
                    )

    doc = {"schema": "bench_pr7/v1", "source": "numpy-mirror", "smoke": smoke, "cells": cells}
    with open(out_path, "w") as f:
        json.dump(doc, f, separators=(",", ":"))
        f.write("\n")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
