"""L1 correctness: the Pallas banded conv-attention kernel vs the dense
jnp oracle — the CORE build-time signal.

Hypothesis sweeps shapes (n, d, k, block size) and basis structure;
fixed-seed cases pin the exact configurations the artifacts bake in.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.conv_attention import (
    conv_apply_pallas,
    conv_attention_pallas,
    mxu_utilization_estimate,
    vmem_footprint_floats,
)
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def make_case(n, d, k, seed, positive=True):
    rng = np.random.default_rng(seed)
    bases = rng.standard_normal((k, n)).astype(np.float32)
    if positive:
        # Post-exp bases are positive and the first window is full —
        # mirrors what exp_transform emits (normalizer must be > 0).
        bases = np.abs(bases) + 0.1
    # Strictly decreasing windows with m_1 = n.
    ms = sorted(rng.choice(np.arange(1, n + 1), size=k, replace=False).tolist(), reverse=True)
    ms[0] = n
    ms = tuple(dict.fromkeys(ms))  # dedupe, keep order
    bases = bases[: len(ms)]
    v = rng.standard_normal((n, d)).astype(np.float32)
    return jnp.asarray(bases), ms, jnp.asarray(v)


@pytest.mark.parametrize("n,d,k,blk", [
    (64, 8, 1, 32),
    (64, 8, 3, 32),
    (128, 16, 4, 64),
    (128, 16, 4, 128),
    (256, 32, 4, 128),  # the default artifact variant
])
def test_kernel_matches_ref_fixed(n, d, k, blk):
    bases, ms, v = make_case(n, d, k, seed=n + d + k)
    o_fast, s_fast = conv_apply_pallas(bases, ms, v, blk=blk)
    o_ref, s_ref = ref.conv_apply_ref(bases, ms, v)
    np.testing.assert_allclose(np.asarray(o_fast), np.asarray(o_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_fast), np.asarray(s_ref), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("blk", [32, 64])
def test_normalized_attention_matches_ref(blk):
    bases, ms, v = make_case(64, 8, 3, seed=7)
    y_fast = conv_attention_pallas(bases, ms, v, blk=blk)
    y_ref = ref.conv_attention_ref(bases, ms, v)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    log_n=st.integers(5, 8),
    d=st.sampled_from([4, 8, 16]),
    k=st.integers(1, 6),
    blk_div=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 10_000),
)
def test_kernel_matches_ref_hypothesis(log_n, d, k, blk_div, seed):
    n = 1 << log_n
    blk = max(8, n // blk_div)
    k = min(k, n)
    bases, ms, v = make_case(n, d, k, seed)
    o_fast, s_fast = conv_apply_pallas(bases, ms, v, blk=blk)
    o_ref, s_ref = ref.conv_apply_ref(bases, ms, v)
    np.testing.assert_allclose(np.asarray(o_fast), np.asarray(o_ref), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(s_fast), np.asarray(s_ref), rtol=5e-4, atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_signed_bases_supported(seed):
    # Negative basis entries arise from the mask-complement correction;
    # the unnormalized kernel must handle them.
    bases, ms, v = make_case(64, 8, 3, seed, positive=False)
    o_fast, s_fast = conv_apply_pallas(bases, ms, v, blk=32)
    o_ref, s_ref = ref.conv_apply_ref(bases, ms, v)
    np.testing.assert_allclose(np.asarray(o_fast), np.asarray(o_ref), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(s_fast), np.asarray(s_ref), rtol=5e-4, atol=5e-4)


def test_identity_basis_is_identity_attention():
    # conv(e_1, n) = I ⇒ attention output = V.
    n, d = 32, 4
    bases = jnp.zeros((1, n), dtype=jnp.float32).at[0, 0].set(1.0)
    v = jnp.asarray(np.random.default_rng(0).standard_normal((n, d)), dtype=jnp.float32)
    y = conv_attention_pallas(bases, (n,), v, blk=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(v), rtol=1e-5, atol=1e-5)


def test_all_ones_basis_is_causal_mean():
    # conv(1, n): row i averages V[0..i] after normalization.
    n, d = 16, 2
    bases = jnp.ones((1, n), dtype=jnp.float32)
    v = jnp.asarray(np.arange(n * d, dtype=np.float32).reshape(n, d))
    y = conv_attention_pallas(bases, (n,), v, blk=16)
    want = np.cumsum(np.asarray(v), axis=0) / np.arange(1, n + 1)[:, None]
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-5)


def test_blk_must_divide_n():
    bases, ms, v = make_case(48, 4, 2, seed=1)
    with pytest.raises(AssertionError):
        conv_apply_pallas(bases, ms, v, blk=32)


def test_vmem_model_monotone_in_blk():
    small = vmem_footprint_floats(4, 2048, 64, 128)
    big = vmem_footprint_floats(4, 2048, 64, 512)
    assert big > small
    # 16 MiB VMEM budget check for the default artifact config.
    assert vmem_footprint_floats(4, 2048, 64, 256) * 4 < 16 * 1024 * 1024


def test_mxu_estimate_in_range():
    u = mxu_utilization_estimate(2048, 256)
    assert 0.5 <= u <= 1.0
