"""L2 checks: model graphs, shapes, and the AOT lowering path."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref


def test_conv_attention_graph_matches_ref():
    var = model.default_variant(n=128, d=16, k=3)
    rng = np.random.default_rng(1)
    bases = jnp.asarray(np.abs(rng.standard_normal((var["k"] - 1, var["n"]))) + 0.1,
                        dtype=jnp.float32)
    # default_variant k=3 → ms has 3 entries; rebuild matching bases.
    bases = jnp.asarray(np.abs(rng.standard_normal((len(var["ms"]), var["n"]))) + 0.1,
                        dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((var["n"], var["d"])), dtype=jnp.float32)
    (y_kernel,) = model.conv_attention(bases, v, ms=var["ms"], blk=64)
    (y_ref,) = model.conv_attention_ref_graph(bases, v, ms=var["ms"])
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


def test_exact_attention_graph_is_softmax():
    n, d = 32, 8
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((n, d)) * 0.3, dtype=jnp.float32)
    k = jnp.asarray(rng.standard_normal((n, d)) * 0.3, dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((n, d)), dtype=jnp.float32)
    (y,) = model.exact_attention(q, k, v)
    # Row 0 attends only to itself.
    np.testing.assert_allclose(np.asarray(y)[0], np.asarray(v)[0], rtol=1e-5)
    # With V = ones, output is ones.
    (y1,) = model.exact_attention(q, k, jnp.ones_like(v))
    np.testing.assert_allclose(np.asarray(y1), 1.0, rtol=1e-5)


def test_default_variant_windows():
    var = model.default_variant(n=256, d=32, k=4)
    assert var["ms"] == (256, 128, 64, 32)
    ms = var["ms"]
    assert all(ms[i] > ms[i + 1] for i in range(len(ms) - 1))


def test_aot_emits_parseable_hlo_text(tmp_path=None):
    with tempfile.TemporaryDirectory() as td:
        text, meta = aot.lower_conv_attention(n=64, d=8, k=2, blk=32)
        assert "HloModule" in text
        assert meta["ms"] == [64, 32]
        path = os.path.join(td, "x.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        assert os.path.getsize(path) > 1000

        text2, meta2 = aot.lower_exact_attention(n=64, d=8)
        assert "HloModule" in text2
        assert meta2["kind"] == "exact_attention"


def test_lowered_conv_artifact_executes_in_jax():
    # Sanity: the lowered computation (with the kernel inside) still
    # produces oracle numerics when compiled by jax itself.
    n, d, k, blk = 64, 8, 2, 32
    var = model.default_variant(n=n, d=d, k=k)

    def fn(bases, v):
        return model.conv_attention(bases, v, ms=var["ms"], blk=blk)

    rng = np.random.default_rng(3)
    bases = jnp.asarray(np.abs(rng.standard_normal((k, n))) + 0.1, dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((n, d)), dtype=jnp.float32)
    (y,) = jax.jit(fn)(bases, v)
    y_ref = ref.conv_attention_ref(bases, var["ms"], v)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
