"""L1 correctness for the Algorithm-4 prefix-scan kernel."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.lowrank_causal import (
    causal_lowrank_attention_pallas,
    causal_lowrank_pallas,
    causal_lowrank_ref,
)


def make_case(n, k, d, seed):
    rng = np.random.default_rng(seed)
    u1 = jnp.asarray(rng.standard_normal((n, k)), dtype=jnp.float32)
    u2 = jnp.asarray(rng.standard_normal((n, k)), dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((n, d)), dtype=jnp.float32)
    return u1, u2, v


@pytest.mark.parametrize("n,k,d,blk", [
    (64, 4, 8, 16),
    (64, 4, 8, 64),
    (128, 16, 32, 64),
    (256, 8, 16, 128),
])
def test_matches_dense_oracle(n, k, d, blk):
    u1, u2, v = make_case(n, k, d, seed=n + k)
    fast = causal_lowrank_pallas(u1, u2, v, blk=blk)
    want = causal_lowrank_ref(u1, u2, v)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    log_n=st.integers(4, 8),
    k=st.sampled_from([1, 4, 8]),
    d=st.sampled_from([2, 8, 16]),
    blk_div=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 10_000),
)
def test_matches_dense_oracle_hypothesis(log_n, k, d, blk_div, seed):
    n = 1 << log_n
    blk = max(4, n // blk_div)
    u1, u2, v = make_case(n, k, d, seed)
    fast = causal_lowrank_pallas(u1, u2, v, blk=blk)
    want = causal_lowrank_ref(u1, u2, v)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(want), rtol=5e-4, atol=5e-4)


def test_block_size_invariance():
    u1, u2, v = make_case(128, 4, 8, seed=3)
    y16 = causal_lowrank_pallas(u1, u2, v, blk=16)
    y128 = causal_lowrank_pallas(u1, u2, v, blk=128)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y128), rtol=2e-4, atol=2e-4)


def test_normalized_attention_rows_sum_to_one_property():
    # With V = 1 columns, normalized output is exactly 1 wherever the
    # row normalizer is nonzero.
    n, k = 64, 4
    rng = np.random.default_rng(5)
    # Positive factors ⇒ positive attention weights ⇒ valid softmax-like
    # normalization.
    u1 = jnp.asarray(np.abs(rng.standard_normal((n, k))) + 0.1, dtype=jnp.float32)
    u2 = jnp.asarray(np.abs(rng.standard_normal((n, k))) + 0.1, dtype=jnp.float32)
    v = jnp.ones((n, 3), dtype=jnp.float32)
    y = causal_lowrank_attention_pallas(u1, u2, v, blk=32)
    np.testing.assert_allclose(np.asarray(y), 1.0, rtol=1e-5)


def test_first_row_attends_only_itself():
    u1, u2, v = make_case(32, 4, 4, seed=9)
    y = causal_lowrank_pallas(u1, u2, v, blk=16)
    want = float(jnp.dot(u1[0], u2[0])) * np.asarray(v[0])
    np.testing.assert_allclose(np.asarray(y[0]), want, rtol=2e-4, atol=2e-4)
